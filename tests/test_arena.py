"""Shared counter arena + zero-loop vectorized collector (PR 3).

Covers arena slot alloc/retire/reuse and growth-rebinding, the
vectorized ``FleetMonitorService.sample()`` path under scrambled
(non-contiguous, unsorted) slot layouts, ``warmup()``'s counter
discard, double-``flush()`` being a no-op, and the one-arena-per-fleet
contract.  PR 9 adds the latency-histogram columns: bucket-quantile
accuracy against a sorted oracle, the batch/scalar recording
equivalence, the benign-race contract under grow/defrag, and the
collector's count-gated window fold.
"""

import gc
import threading
import time

import numpy as np
import pytest

from repro.core.monitor import MonitorConfig, run_monitor_fleet
from repro.streams import (CounterArena, EndStats, FleetMonitorService,
                           InstrumentedQueue)
from repro.streams.arena import (LAT_BOUNDS, LAT_BUCKETS, hist_quantiles,
                                 lat_bucket)


def _drive(svc, queues, tc, blocked=None):
    Q, T = tc.shape
    for t in range(T):
        for qi, q in enumerate(queues):
            q.head.tc = float(tc[qi, t])
            if blocked is not None:
                q.head.blocked = bool(blocked[qi, t])
        svc.sample()
    svc.flush()


def test_arena_slot_reuse_after_queue_retirement():
    """Satellite: a closed queue's slots go back to the arena and back
    the next queue, instead of growing the arena forever."""
    arena = CounterArena(capacity=8)
    q = InstrumentedQueue(2, arena=arena)
    slots = {q.head.slot, q.tail.slot}
    assert len(arena) == 2
    q.close()
    q.close()                         # idempotent
    assert len(arena) == 0
    q2 = InstrumentedQueue(2, arena=arena)
    assert {q2.head.slot, q2.tail.slot} == slots
    assert arena.capacity == 8        # no growth


def test_arena_slots_released_on_gc():
    arena = CounterArena(capacity=4)
    q = InstrumentedQueue(2, arena=arena)
    slots = {q.head.slot, q.tail.slot}
    del q
    gc.collect()
    assert len(arena) == 0
    q2 = InstrumentedQueue(2, arena=arena)
    assert {q2.head.slot, q2.tail.slot} == slots


def test_arena_growth_rebinds_live_views():
    """Growing the arena replaces the arrays; live EndStats views must
    keep their values and keep writing to the *new* arrays."""
    arena = CounterArena(capacity=2)
    e = arena.alloc()
    e.tc = 7
    e.bytes_count = 40
    keep = [arena.alloc() for _ in range(9)]    # forces growth
    assert arena.capacity >= 10
    assert e.tc == 7 and e.bytes_count == 40
    e.tc += 1
    assert arena.tc[e.slot] == 8                # writes land in new array
    assert len(keep) == 9


def test_fleet_requires_single_arena():
    q1 = InstrumentedQueue(2, arena=CounterArena(4))
    q2 = InstrumentedQueue(2, arena=CounterArena(4))
    with pytest.raises(ValueError, match="one CounterArena"):
        FleetMonitorService([q1, q2], MonitorConfig())


def test_vectorized_sample_with_scrambled_slots_matches_oracle():
    """The zero-loop collector must be exact under non-contiguous,
    unsorted slot layouts (retired slots, out-of-order queue lists) —
    the fancy-index + permutation path, not just the slice fast path."""
    cfg = MonitorConfig()
    rng = np.random.default_rng(5)
    arena = CounterArena(capacity=8)
    made, holes = [], []
    for _ in range(5):
        made.append(InstrumentedQueue(4, arena=arena))
        holes.append(EndStats(arena))  # punch holes between queue slots
    made[1].close()                    # retire one mid-range queue
    queues = [made[4], made[0], made[3], made[2]]   # scrambled order
    slots = [q.head.slot for q in queues]
    assert slots != sorted(slots)                      # unsorted
    assert sorted(slots) != list(range(min(slots),
                                       min(slots) + 4))  # with gaps

    Q, T = 4, 480
    tc = rng.poisson(rng.uniform(100, 400, (Q, 1)), (Q, T)).astype(float)
    blocked = rng.random((Q, T)) < 0.05
    svc = FleetMonitorService(queues, cfg, period_s=1e-3, chunk_t=32,
                              scale_to_period=False)
    _drive(svc, queues, tc, blocked)

    st, _ = run_monitor_fleet(cfg, tc, blocked, impl="scan", mode="state")
    np.testing.assert_array_equal(svc.epochs(), np.asarray(st.epoch))
    conv = svc.epochs() > 0
    assert conv.any()
    got = svc.service_rates() * svc.period_s
    want = np.asarray(st.last_qbar)
    np.testing.assert_allclose(got[conv], want[conv], rtol=1e-4)


def test_warmup_discards_accumulated_counters():
    """Satellite: whatever the queues counted while warmup() compiled
    must be dropped — the first real tick must not fold the compile
    interval as one nominal period."""
    cfg = MonitorConfig(window=8, min_q_samples=8)
    arena = CounterArena(capacity=8)
    queues = [InstrumentedQueue(4, arena=arena) for _ in range(2)]
    svc = FleetMonitorService(queues, cfg, period_s=1e-3, chunk_t=8,
                              scale_to_period=False, ends="both")
    for q in queues:
        q.head.tc = 123.0
        q.head.blocked = True
        q.tail.tc = 7.0
        q.tail.bytes_count = 99
    svc.warmup()
    for q in queues:
        assert q.head.tc == 0 and q.tail.tc == 0
        assert not q.head.blocked
        assert q.tail.bytes_count == 0
    assert svc._last_t is not None
    # the discarded counts never reach the estimator
    assert not svc.sample()
    np.testing.assert_array_equal(svc._tc_shadow, 0.0)
    np.testing.assert_array_equal(svc._tc[0], 0.0)


def test_flush_twice_is_no_op():
    """Satellite: a second flush() must not double-harvest — no new
    dispatch, no epoch movement, no repeated convergence callbacks."""
    cfg = MonitorConfig(window=8, min_q_samples=8)
    arena = CounterArena(capacity=8)
    queues = [InstrumentedQueue(4, arena=arena) for _ in range(2)]
    emits = []
    svc = FleetMonitorService(
        queues, cfg, period_s=1e-3, chunk_t=8, scale_to_period=False,
        on_fleet=lambda idx, rates: emits.append((idx.copy(),
                                                  rates.copy())))
    for _ in range(60):
        for q in queues:
            q.head.tc = 10.0
        svc.sample()
    svc.flush()
    assert svc.epochs().min() >= 1          # converged at least once
    dispatches = svc.dispatches
    epochs = svc.epochs()
    n_emits = len(emits)

    svc.flush()
    assert svc.dispatches == dispatches
    np.testing.assert_array_equal(svc.epochs(), epochs)
    assert len(emits) == n_emits


def test_close_refused_while_monitored():
    """Releasing a monitored slot would recycle it under a live
    collector that keeps zeroing it — close() must refuse until the
    service is gone."""
    arena = CounterArena(capacity=8)
    queues = [InstrumentedQueue(4, arena=arena) for _ in range(2)]
    svc = FleetMonitorService(queues, MonitorConfig(), period_s=1e-3,
                              chunk_t=4, ends="both")
    with pytest.raises(ValueError, match="monitors it"):
        queues[0].close()
    del svc
    gc.collect()                      # dead service un-pins (WeakSet)
    queues[0].close()
    assert len(arena) == 2            # only queues[1]'s ends remain


def test_default_arena_shared_across_queues():
    """Queues without an explicit arena share the process-wide default,
    so any ad-hoc mix of them can ride one FleetMonitorService."""
    q1 = InstrumentedQueue(2)
    q2 = InstrumentedQueue(2)
    assert q1.arena is q2.arena
    svc = FleetMonitorService([q1, q2], MonitorConfig(), period_s=1e-3,
                              chunk_t=4, ends="both")
    assert svc.n_streams == 4


def test_fragmentation_metric_and_explicit_defrag():
    """Satellite (PR 4): holes left by retired slots are measurable and
    compactable; counter values ride along and views rebind."""
    arena = CounterArena(capacity=16, defrag_threshold=2.0)  # manual only
    qs = [InstrumentedQueue(2, arena=arena) for _ in range(4)]  # slots 0..7
    assert arena.fragmentation() == 0.0
    qs[3].head.tc = 5.0
    qs[3].tail.bytes_count = 77
    qs[1].close()
    qs[2].close()
    # live slots {0,1,6,7}: span 8, 4 live -> half the span is holes
    assert arena.fragmentation() == pytest.approx(0.5)
    v0 = arena.layout_version
    assert arena.defragment() is True
    assert arena.layout_version == v0 + 1
    assert arena.fragmentation() == 0.0
    assert sorted([qs[0].head.slot, qs[0].tail.slot,
                   qs[3].head.slot, qs[3].tail.slot]) == [0, 1, 2, 3]
    # values moved with the ends, and live views write to the new cells
    assert qs[3].head.tc == 5.0 and qs[3].tail.bytes_count == 77
    qs[3].head.tc += 1.0
    assert arena.tc[qs[3].head.slot] == 6.0
    assert arena.defragment() is False           # already compact
    # retire-after-defrag recycles the *new* slot numbers (finalizers
    # were rebuilt): allocating again reuses the low compacted range
    qs[0].close()
    q_new = InstrumentedQueue(2, arena=arena)
    assert {q_new.head.slot, q_new.tail.slot} <= set(range(4))


def test_auto_defrag_on_retire_regains_contiguity():
    """Retiring most of a fleet auto-compacts once fragmentation passes
    the threshold, so the survivors co-allocate low and a fresh service
    over them rides the slice fast path again."""
    arena = CounterArena(capacity=32, defrag_threshold=0.3)
    old = [InstrumentedQueue(2, arena=arena) for _ in range(6)]
    keep = old[5]                      # starts at slots 10, 11
    assert keep.head.slot == 10
    for q in old[:5]:
        q.close()
    assert keep.head.slot == 0 and keep.tail.slot == 1
    assert arena.fragmentation() == 0.0
    svc = FleetMonitorService([keep], MonitorConfig(), period_s=1e-3,
                              chunk_t=4, ends="both",
                              scale_to_period=False)
    assert isinstance(svc._slots, slice)         # slice fast path


def test_live_service_survives_defrag_mid_stream():
    """Defrag moves monitored (pinned) slots; a live service re-derives
    its slot index from layout_version on the next tick and the
    estimates stay exact vs the scan oracle across the move."""
    cfg = MonitorConfig()
    rng = np.random.default_rng(9)
    arena = CounterArena(capacity=32, defrag_threshold=2.0)
    junk = [InstrumentedQueue(2, arena=arena) for _ in range(3)]
    queues = [InstrumentedQueue(4, arena=arena) for _ in range(4)]
    svc = FleetMonitorService(queues, cfg, period_s=1e-3, chunk_t=32,
                              scale_to_period=False, ends="both")
    assert svc._slots == slice(6, 14)

    Q, T = 4, 480
    tc = rng.poisson(rng.uniform(100, 400, (Q, 1)), (Q, T)).astype(float)
    blocked = rng.random((Q, T)) < 0.05

    def drive(t0, t1):
        for t in range(t0, t1):
            for qi, q in enumerate(queues):
                q.head.tc = float(tc[qi, t])
                q.head.blocked = bool(blocked[qi, t])
            svc.sample()

    drive(0, T // 2)
    for q in junk:
        q.close()                      # punch 6 holes below the fleet
    assert arena.defragment() is True  # monitored slots move
    drive(T // 2, T)
    svc.flush()
    assert svc._slots == slice(0, 8)   # slice fast path regained live

    st, _ = run_monitor_fleet(cfg, tc, blocked, impl="scan", mode="state")
    np.testing.assert_array_equal(svc.epochs()[:Q], np.asarray(st.epoch))
    conv = svc.epochs()[:Q] > 0
    assert conv.any()
    got = svc.service_rates() * svc.period_s
    want = np.asarray(st.last_qbar)
    np.testing.assert_allclose(got[:Q][conv], want[conv], rtol=1e-4)


# -- PR 9: per-slot latency histograms (SLO observability plane) -------------


def test_latency_histogram_quantiles_vs_sorted_oracle():
    """Tentpole: bucket-interpolated quantiles must land within one
    bucket width of the exact sorted-sample quantile — the resolution
    the log-spaced layout promises, on a realistic heavy-tailed mix."""
    rng = np.random.default_rng(11)
    samples = np.exp(rng.normal(-4.0, 1.2, 5000))     # ~67us median tail
    arena = CounterArena(capacity=4)
    q = InstrumentedQueue(2, arena=arena)
    q.head.record_latency(samples)
    hist = q.head.latency_histogram()
    assert int(hist.sum()) == samples.size
    qs = (0.5, 0.9, 0.99, 0.999)
    est = hist_quantiles(hist[None, :].astype(np.int64), qs)[0]
    assert np.all(np.diff(est) >= 0)                  # monotone in q
    for j, p in enumerate(qs):
        exact = float(np.quantile(samples, p))
        b = int(lat_bucket(exact))
        width = LAT_BOUNDS[b + 1] - LAT_BOUNDS[b]
        assert abs(est[j] - exact) <= width, (p, est[j], exact)


def test_hist_quantiles_empty_rows_nan():
    """A row with zero observations is "no evidence", not "zero
    latency": NaN, while populated rows interpolate inside their
    bucket's bounds."""
    hist = np.zeros((3, LAT_BUCKETS), np.int64)
    hist[1, 5] = 10
    out = hist_quantiles(hist)
    assert np.isnan(out[0]).all() and np.isnan(out[2]).all()
    assert np.isfinite(out[1]).all()
    assert (LAT_BOUNDS[5] <= out[1]).all()
    assert (out[1] <= LAT_BOUNDS[6]).all()


def test_record_latency_batch_matches_scalar_fold():
    """The bincount batch path and the single-cell scalar path must
    produce identical rows and identical change-detector counts —
    including underflow (< first edge) and overflow (> last edge)."""
    arena = CounterArena(capacity=4)
    qa = InstrumentedQueue(2, arena=arena)
    qb = InstrumentedQueue(2, arena=arena)
    samples = np.array([1e-6, 1e-4, 5e-3, 5e-3, 0.2, 3.0, 150.0])
    qa.head.record_latency(samples, n=3)
    for s in samples:
        for _ in range(3):
            qb.head.record_latency(float(s))
    np.testing.assert_array_equal(qa.head.latency_histogram(),
                                  qb.head.latency_histogram())
    assert arena.lat_count[qa.head.slot] == samples.size * 3
    assert arena.lat_count[qb.head.slot] == samples.size * 3


def test_record_latency_race_with_grow_and_defrag_never_misattributes():
    """Benign-race contract: a hot recorder racing arena growth,
    defragmentation and slot recycling may *lose* increments (they land
    on abandoned arrays) but must never misattribute them to another
    slot's next owner, and the change-detector count stays consistent
    with the row."""
    arena = CounterArena(capacity=4, defrag_threshold=2.0)  # manual defrag
    hot = InstrumentedQueue(2, arena=arena)
    stop = threading.Event()
    recorded = [0]

    def pound():
        end = hot.head
        while not stop.is_set():
            recorded[0] += 1
            end.record_latency(1e-3)

    th = threading.Thread(target=pound)
    th.start()
    try:
        live = []
        for _ in range(40):                   # repeated growth rebinding
            live.append(InstrumentedQueue(2, arena=arena))
        for q in live[::2]:
            q.close()                         # punch holes...
        assert arena.defragment() is True     # ...and move every slot
        live2 = [InstrumentedQueue(2, arena=arena) for _ in range(10)]
        # let the recorder land increments on the *post-churn* arrays
        # too (pre-churn ones may be benignly lost to abandoned arrays)
        seen = int(hot.head.latency_histogram().sum())
        deadline = time.monotonic() + 5.0
        while seen == 0 and time.monotonic() < deadline:
            time.sleep(0.005)
            seen = int(hot.head.latency_histogram().sum())
    finally:
        stop.set()
        th.join()
    hist = hot.head.latency_histogram()
    total = int(hist.sum())
    b = int(lat_bucket(1e-3))
    assert 0 < total <= recorded[0]
    assert hist[b] == total                   # one bucket, nothing smeared
    assert int(arena.lat_count[hot.head.slot]) <= recorded[0]
    # nobody else's row caught a stray increment
    for q in live[1::2] + live2:
        assert int(q.head.latency_histogram().sum()) == 0
        assert int(q.tail.latency_histogram().sum()) == 0
    hot.close()


def test_fleet_window_fold_count_gated():
    """Collector harvest semantics: percentiles/over-fraction reflect
    the *last non-empty window* per queue (NaN = never observed), an
    empty follow-up window reads as no-evidence over-fraction while
    percentiles hold, and cumulative counts only ever grow."""
    arena = CounterArena(capacity=8)
    queues = [InstrumentedQueue(4, arena=arena) for _ in range(3)]
    svc = FleetMonitorService(queues, MonitorConfig(window=8,
                                                    min_q_samples=8),
                              period_s=1e-3, chunk_t=2,
                              scale_to_period=False, ends="both")
    svc.sample()
    svc.sample()                              # anchors the window clock
    queues[0].head.record_latency(np.full(100, 2e-3))
    queues[1].head.record_error(7)
    svc.sample()
    svc.sample()                              # chunk boundary -> harvest

    p = svc.latency_percentiles(which="head")
    assert p.shape == (3, 4)
    assert np.isfinite(p[0]).all()
    assert np.isnan(p[1]).all() and np.isnan(p[2]).all()
    over = svc.over_fraction([1e-3, 1e-3, 1e-3], which="head")
    assert over[0] == pytest.approx(1.0)      # 2e-3 >> 1e-3, whole window
    assert np.isnan(over[1]) and np.isnan(over[2])
    np.testing.assert_array_equal(svc.latency_counts(which="head"),
                                  [100, 0, 0])
    np.testing.assert_array_equal(svc.error_totals(which="head"),
                                  [0, 7, 0])
    assert svc.error_rates(which="head")[1] > 0

    svc.sample()                              # empty window
    svc.sample()
    over2 = svc.over_fraction([1e-3, 1e-3, 1e-3], which="head")
    assert np.isnan(over2).all()              # no evidence anywhere now
    p2 = svc.latency_percentiles(which="head")
    np.testing.assert_array_equal(p2[0], p[0])   # held, not wiped
    np.testing.assert_array_equal(svc.latency_counts(which="head"),
                                  [100, 0, 0])
    np.testing.assert_array_equal(svc.error_totals(which="head"),
                                  [0, 7, 0])
    assert svc.error_rates(which="head")[1] == 0.0

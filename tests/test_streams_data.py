import threading
import time

import numpy as np
import pytest

from repro.core.monitor import MonitorConfig
from repro.data import DataPipeline, SyntheticLMSource, pack_tokens
from repro.streams import InstrumentedQueue, Pipeline, Stage


def test_queue_fifo_and_counters():
    q = InstrumentedQueue(4, item_bytes=8)
    assert q.try_push(1) and q.try_push(2)
    assert q.tail.tc == 2
    assert q.try_pop() == 1
    assert q.head.tc == 1
    tc, blocked, nbytes = q.head.sample_and_reset()
    assert (tc, blocked, nbytes) == (1, False, 8)
    assert q.head.tc == 0


def test_queue_blocking_flags():
    q = InstrumentedQueue(2)
    q.try_push("a")
    q.try_push("b")
    assert not q.try_push("c")        # full
    assert q.tail.blocked
    q2 = InstrumentedQueue(2)
    assert q2.try_pop() is None       # empty
    assert q2.head.blocked


def test_queue_resize_preserves_items():
    q = InstrumentedQueue(4)
    for i in range(4):
        q.try_push(i)
    assert q.resize(16) is True
    assert q.capacity == 16
    assert [q.try_pop() for _ in range(4)] == [0, 1, 2, 3]


def test_queue_resize_rejections_return_false():
    """Satellite: rejected resizes (capacity < 1, shrink below the
    queued-item count) report False and leave the queue intact."""
    q = InstrumentedQueue(4)
    for i in range(3):
        q.try_push(i)
    assert q.resize(0) is False
    assert q.resize(2) is False           # would drop an item
    assert q.capacity == 4
    assert q.resize(3) is True            # exact fit is allowed
    assert q.capacity == 3
    assert [q.try_pop() for _ in range(3)] == [0, 1, 2]


def test_queue_resize_to_non_pow2_wraps_correctly():
    """Bitmask indexing must be dropped when a resize lands on a
    non-power-of-two capacity (and picked back up on a pow2 one)."""
    q = InstrumentedQueue(4)
    assert q.resize(6) is True
    for rounds in range(5):               # force index wrap-around
        for i in range(6):
            assert q.try_push((rounds, i))
        assert not q.try_push("overflow")
        assert [q.try_pop() for _ in range(6)] == \
            [(rounds, i) for i in range(6)]
    assert q.resize(8) is True
    for i in range(8):
        assert q.try_push(i)
    assert [q.try_pop() for _ in range(8)] == list(range(8))


def test_queue_resize_concurrent_with_push_pop():
    """Regression: a controller resize rebases _head/_tail while a
    producer is mid-push — both ends must serialize buffer/index
    updates against resize, or items are lost/duplicated."""
    q = InstrumentedQueue(8)
    n = 20_000
    out = []
    stop = threading.Event()

    def producer():
        for i in range(n):
            q.push(i)

    def consumer():
        while len(out) < n:
            item = q.pop(timeout=5.0)
            if item is not None:
                out.append(item)

    def resizer():
        caps = [5, 16, 7, 64, 9, 32]
        i = 0
        while not stop.is_set():
            q.resize(caps[i % len(caps)])
            i += 1
            time.sleep(2e-4)

    tp = threading.Thread(target=producer)
    tc_ = threading.Thread(target=consumer)
    tr = threading.Thread(target=resizer, daemon=True)
    tp.start(); tc_.start(); tr.start()
    tp.join(30); tc_.join(30)
    stop.set(); tr.join(5)
    assert out == list(range(n))      # SPSC ordering + no loss


def test_queue_none_payload_roundtrips():
    """Satellite regression: a stored None is an item, not emptiness —
    pop must return it immediately and in order instead of spinning
    until timeout."""
    q = InstrumentedQueue(4)
    q.push(None)
    q.push(5)
    t0 = time.monotonic()
    assert q.pop(timeout=5.0) is None     # the payload, not a timeout
    assert time.monotonic() - t0 < 1.0    # ...returned immediately
    assert q.pop(timeout=5.0) == 5
    # try_pop distinguishes via a caller-supplied default
    sentinel = object()
    q.try_push(None)
    assert q.try_pop(sentinel) is None    # stored None comes out
    assert q.try_pop(sentinel) is sentinel  # now actually empty


def test_queue_threaded_integrity():
    q = InstrumentedQueue(32)
    n = 20_000
    out = []

    def producer():
        for i in range(n):
            q.push(i)

    def consumer():
        while len(out) < n:
            item = q.pop(timeout=5.0)
            if item is not None:
                out.append(item)

    tp, tc_ = threading.Thread(target=producer), threading.Thread(
        target=consumer)
    tp.start(); tc_.start()
    tp.join(30); tc_.join(30)
    assert out == list(range(n))      # SPSC ordering + no loss
    assert q.head.tc + 0 >= 0         # counters valid


def test_pipeline_end_to_end_counts():
    pipe = Pipeline([Stage("src", source=range(5000)),
                     Stage("x2", fn=lambda x: x * 2)], capacity=64,
                    base_period_s=2e-3,
                    monitor_cfg=MonitorConfig(window=16,
                                              min_q_samples=16))
    out = pipe.run_collect(timeout_s=60)
    assert sorted(out) == [2 * i for i in range(5000)]
    rates = pipe.rates()
    assert len(rates) == 2


def test_pack_tokens_exact_windows():
    docs = iter([np.arange(10, dtype=np.int32),
                 np.arange(100, 120, dtype=np.int32)])
    seqs = list(pack_tokens(docs, seq_len=7))
    assert all(s.shape == (8,) for s in seqs)
    flat = np.concatenate(seqs)
    # first doc then EOS(0) then second doc
    np.testing.assert_array_equal(flat[:10], np.arange(10))
    assert flat[10] == 0
    np.testing.assert_array_equal(flat[11:24], np.arange(100, 113))


def test_data_pipeline_batches():
    src = SyntheticLMSource(vocab_size=100, doc_len=64, seed=0)
    dp = DataPipeline(src, seq_len=32, batch_size=4, max_batches=5).start()
    batches = list(dp)
    dp.stop()
    assert len(batches) == 5
    for b in batches:
        assert b["tokens"].shape == (4, 32)
        assert b["targets"].shape == (4, 32)
        np.testing.assert_array_equal(b["tokens"][:, 1:],
                                      b["targets"][:, :-1])
        assert b["tokens"].max() < 101

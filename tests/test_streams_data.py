import threading
import time

import numpy as np
import pytest

from repro.core.monitor import MonitorConfig
from repro.data import DataPipeline, SyntheticLMSource, pack_tokens
from repro.streams import InstrumentedQueue, Pipeline, Stage


def test_queue_fifo_and_counters():
    q = InstrumentedQueue(4, item_bytes=8)
    assert q.try_push(1) and q.try_push(2)
    assert q.tail.tc == 2
    assert q.try_pop() == 1
    assert q.head.tc == 1
    tc, blocked, nbytes = q.head.sample_and_reset()
    assert (tc, blocked, nbytes) == (1, False, 8)
    assert q.head.tc == 0


def test_queue_blocking_flags():
    q = InstrumentedQueue(2)
    q.try_push("a")
    q.try_push("b")
    assert not q.try_push("c")        # full
    assert q.tail.blocked
    q2 = InstrumentedQueue(2)
    assert q2.try_pop() is None       # empty
    assert q2.head.blocked


def test_queue_resize_preserves_items():
    q = InstrumentedQueue(4)
    for i in range(4):
        q.try_push(i)
    q.resize(16)
    assert q.capacity == 16
    assert [q.try_pop() for _ in range(4)] == [0, 1, 2, 3]


def test_queue_threaded_integrity():
    q = InstrumentedQueue(32)
    n = 20_000
    out = []

    def producer():
        for i in range(n):
            q.push(i)

    def consumer():
        while len(out) < n:
            item = q.pop(timeout=5.0)
            if item is not None:
                out.append(item)

    tp, tc_ = threading.Thread(target=producer), threading.Thread(
        target=consumer)
    tp.start(); tc_.start()
    tp.join(30); tc_.join(30)
    assert out == list(range(n))      # SPSC ordering + no loss
    assert q.head.tc + 0 >= 0         # counters valid


def test_pipeline_end_to_end_counts():
    pipe = Pipeline([Stage("src", source=range(5000)),
                     Stage("x2", fn=lambda x: x * 2)], capacity=64,
                    base_period_s=2e-3,
                    monitor_cfg=MonitorConfig(window=16,
                                              min_q_samples=16))
    out = pipe.run_collect(timeout_s=60)
    assert sorted(out) == [2 * i for i in range(5000)]
    rates = pipe.rates()
    assert len(rates) == 2


def test_pack_tokens_exact_windows():
    docs = iter([np.arange(10, dtype=np.int32),
                 np.arange(100, 120, dtype=np.int32)])
    seqs = list(pack_tokens(docs, seq_len=7))
    assert all(s.shape == (8,) for s in seqs)
    flat = np.concatenate(seqs)
    # first doc then EOS(0) then second doc
    np.testing.assert_array_equal(flat[:10], np.arange(10))
    assert flat[10] == 0
    np.testing.assert_array_equal(flat[11:24], np.arange(100, 113))


def test_data_pipeline_batches():
    src = SyntheticLMSource(vocab_size=100, doc_len=64, seed=0)
    dp = DataPipeline(src, seq_len=32, batch_size=4, max_batches=5).start()
    batches = list(dp)
    dp.stop()
    assert len(batches) == 5
    for b in batches:
        assert b["tokens"].shape == (4, 32)
        assert b["targets"].shape == (4, 32)
        np.testing.assert_array_equal(b["tokens"][:, 1:],
                                      b["targets"][:, :-1])
        assert b["tokens"].max() < 101

"""Prometheus-style observability plane (repro.obs) — PR 9.

Covers: well-formed text exposition (every sample line parses, one
HELP/TYPE per family), the single-snapshot consistency surface over
the fleet SLO mirrors, the three HTTP endpoints (/metrics,
/control_log drain with ring-drop acknowledgement, /healthz), the
``obs=`` knob resolution shared by Engine/ControlGroup/Pipeline, and
the monitor=False rejection.
"""

import json
import re
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.control import (ControlGroup, ControlLog, ControlLoop,
                           ControlRecord, PolicySet, ReplicaPolicy,
                           SLOPolicy)
from repro.core.monitor import MonitorConfig
from repro.obs import MetricsExporter, make_exporter, render_metrics
from repro.streams import (CounterArena, FleetMonitorService,
                           InstrumentedQueue, Pipeline, Stage)

# one exposition sample line: name{label="v",...} value
SAMPLE = re.compile(
    r'^[a-zA-Z_:][a-zA-Z0-9_:]*'
    r'(\{[a-zA-Z_][a-zA-Z0-9_]*="[^"]*"'
    r'(,[a-zA-Z_][a-zA-Z0-9_]*="[^"]*")*\})? '
    r'(NaN|[+-]Inf|-?\d+(\.\d+)?([eE][+-]?\d+)?)$')


def _assert_well_formed(text):
    families = []
    for line in text.splitlines():
        if line.startswith("# HELP ") or line.startswith("# TYPE "):
            if line.startswith("# HELP "):
                families.append(line.split()[2])
            continue
        assert SAMPLE.match(line), f"malformed sample line: {line!r}"
    assert len(families) == len(set(families)), "HELP emitted twice"
    return families


def _stack():
    """Tiny fleet + control loop with real harvested latency/errors."""
    arena = CounterArena(8)
    queues = [InstrumentedQueue(8, arena=arena) for _ in range(2)]
    svc = FleetMonitorService(queues, MonitorConfig(window=8,
                                                    min_q_samples=8),
                              period_s=1e-3, chunk_t=2,
                              scale_to_period=False, ends="both")
    class _Act:
        def replicas(self):
            return np.array([1, 1], np.int64)

        def capacities(self):
            return np.array([8, 8], np.int64)

        def occupancy(self):
            return np.zeros(2)

        def scale(self, i, n):
            return "applied"

        def resize(self, i, cap):
            return "applied"

        def admit(self, i, shed):
            return "applied"

    loop = ControlLoop(svc, PolicySet(replica=ReplicaPolicy(),
                                      slo=SLOPolicy(target_s=4e-3),
                                      block_q=8), _Act())
    svc.sample()
    svc.sample()                          # anchor the SLO window clock
    queues[0].head.record_latency(np.full(50, 2e-3))
    queues[1].head.record_error(3)
    svc.sample()
    svc.sample()                          # chunk boundary -> harvest
    loop.tick()
    return arena, queues, svc, loop


def test_render_metrics_well_formed_and_complete():
    _, queues, svc, loop = _stack()
    loop.log.append(ControlRecord(
        t=0.0, tick=0, queue=0, policy="replicas", observed_lam=1.0,
        observed_mu=2.0, action="scale", value=3, outcome="applied"))
    text = render_metrics(svc, loop, names=["alpha", "beta"])
    families = _assert_well_formed(text)
    for fam in ("repro_stream_rate_items_per_s", "repro_latency_seconds",
                "repro_latency_observations_total", "repro_errors_total",
                "repro_error_rate_per_s", "repro_periods_total",
                "repro_monitor_dispatches_total", "repro_slo_burn_rate",
                "repro_slo_target_seconds", "repro_control_ticks_total",
                "repro_control_log_dropped_total",
                "repro_control_decisions_total",
                "repro_exporter_scrapes_total"):
        assert fam in families, f"missing family {fam}"
    # queue labels carry the caller's names
    assert 'queue="0",name="alpha"' in text
    # the harvested window is in the exposition: 50 observations on
    # queue 0, 3 errors on queue 1, NaN percentiles where never observed
    assert ('repro_latency_observations_total'
            '{queue="0",name="alpha"} 50') in text
    assert 'repro_errors_total{queue="1",name="beta"} 3' in text
    assert re.search(r'repro_latency_seconds\{queue="0",name="alpha",'
                     r'quantile="0\.5"\} 0\.00\d', text)
    assert re.search(r'repro_latency_seconds\{queue="1",name="beta",'
                     r'quantile="0\.5"\} NaN', text)
    assert 'repro_control_decisions_total{policy="replicas"'in text


def test_exporter_http_endpoints():
    _, queues, svc, loop = _stack()
    log = loop.log
    for i in range(5):
        log.append(ControlRecord(
            t=float(i), tick=i, queue=0, policy="replicas", observed_lam=1.0,
            observed_mu=2.0, action="scale", value=2, outcome="noop"))
    with MetricsExporter(service=svc, loop=loop) as ex:
        assert ex.port and ex.url
        r = urllib.request.urlopen(ex.url + "/metrics", timeout=10)
        assert r.status == 200
        assert r.headers["Content-Type"].startswith("text/plain")
        _assert_well_formed(r.read().decode())

        h = json.loads(urllib.request.urlopen(
            ex.url + "/healthz", timeout=10).read())
        assert h["ok"] is True and h["ticks"] >= 1

        lines = urllib.request.urlopen(
            ex.url + "/control_log", timeout=10).read().decode()
        recs = [json.loads(ln) for ln in lines.splitlines()]
        ts = [r["t"] for r in recs if r.get("policy") == "replicas"]
        assert ts == [float(i) for i in range(5)]
        # the drain cursor advanced: a second GET returns nothing new
        again = urllib.request.urlopen(
            ex.url + "/control_log", timeout=10).read().decode()
        assert again == ""
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(ex.url + "/nope", timeout=10)
    assert ex.port is None                # stopped


def test_control_log_endpoint_acknowledges_ring_drops():
    log = ControlLog(capacity=2)
    for i in range(5):
        log.append(ControlRecord(
            t=float(i), tick=i, queue=0, policy="loop", observed_lam=0.0,
            observed_mu=0.0, action="tick", value=0, outcome="observed"))
    assert log.dropped_total == 3
    with MetricsExporter(log=log) as ex:
        lines = urllib.request.urlopen(
            ex.url + "/control_log", timeout=10).read().decode()
    recs = [json.loads(ln) for ln in lines.splitlines()]
    assert recs[0] == {"dropped": 3}      # holes acknowledged, not silent
    assert [r["t"] for r in recs[1:]] == [3.0, 4.0]


def test_make_exporter_knob_forms():
    assert make_exporter(None) is None
    assert make_exporter(False) is None
    ex = make_exporter(True)
    assert isinstance(ex, MetricsExporter) and ex.port is None
    ex = make_exporter(9137)
    assert ex._want_port == 9137          # int = that port (not started)
    ex = make_exporter({"host": "127.0.0.1"}, port=7)
    assert ex.host == "127.0.0.1" and ex._want_port == 7
    adopted = MetricsExporter()
    assert make_exporter(adopted) is adopted
    with pytest.raises(TypeError, match="obs="):
        make_exporter("yes")


def test_group_obs_knob_wires_shared_mirrors():
    group = ControlGroup(PolicySet(replica=ReplicaPolicy(), block_q=8),
                         arena=CounterArena(8),
                         monitor_cfg=MonitorConfig(window=8,
                                                   min_q_samples=8),
                         obs=True)
    try:
        ex = group.exporter
        assert isinstance(ex, MetricsExporter)
        assert ex.service is group.service and ex.loop is group.loop
        _assert_well_formed(ex.render())  # renders even while fleet empty
    finally:
        group.stop()
    off = ControlGroup(PolicySet(replica=ReplicaPolicy(), block_q=8),
                       arena=CounterArena(8),
                       monitor_cfg=MonitorConfig(window=8,
                                                 min_q_samples=8))
    assert off.exporter is None
    off.stop()


def test_exporter_concurrent_scrapes_drain_and_defrag():
    """Parallel /metrics scrapes race a /control_log drain, a hot
    writer, collector ticks and a mid-scrape arena defrag (queue churn
    past ``defrag_threshold`` moves live slots while snapshots are
    being rendered).  Every response must stay well-formed, and the
    drain cursor must hand each record to exactly one scraper.  Runs
    under the conftest LockWitness, so any hierarchy inversion or
    same-tier ABBA cycle on the way fails the test too."""
    arena = CounterArena(64, defrag_threshold=0.3)
    queues = [InstrumentedQueue(8, arena=arena) for _ in range(4)]
    svc = FleetMonitorService(queues, MonitorConfig(window=8,
                                                    min_q_samples=8),
                              period_s=1e-3, chunk_t=2,
                              scale_to_period=False, ends="both")
    log = ControlLog(capacity=4096)
    errors, drained = [], []
    drained_lock = threading.Lock()
    stop = threading.Event()

    def guard(fn):
        def run():
            try:
                fn()
            except Exception as e:   # pragma: no cover - reraised below
                errors.append(e)
                stop.set()
        return run

    def writer():
        for i in range(300):
            log.append(ControlRecord(
                t=float(i), tick=i, queue=i % 4, policy="replicas",
                observed_lam=1.0, observed_mu=2.0, action="scale",
                value=2, outcome="applied"))
            if stop.is_set():
                return
        stop.set()

    def churn():
        # allocate/close extra queues so retirements push fragmentation
        # past the threshold -> compact-on-retire relocates live slots
        while not stop.is_set():
            extra = [InstrumentedQueue(4, arena=arena) for _ in range(6)]
            for q in extra[::2]:
                q.close()
            for q in extra[1::2]:
                q.close()

    def sampler():
        while not stop.is_set():
            queues[0].head.record_latency(np.full(8, 2e-3))
            svc.sample()

    with MetricsExporter(service=svc, log=log) as ex:
        def scraper():
            while not stop.is_set():
                text = urllib.request.urlopen(
                    ex.url + "/metrics", timeout=10).read().decode()
                _assert_well_formed(text)

        def drainer():
            while not stop.is_set():
                lines = urllib.request.urlopen(
                    ex.url + "/control_log", timeout=10).read().decode()
                ts = [json.loads(ln)["t"] for ln in lines.splitlines()
                      if "dropped" not in json.loads(ln)]
                assert ts == sorted(ts), "drain response out of order"
                with drained_lock:
                    drained.extend(ts)

        threads = [threading.Thread(target=guard(fn)) for fn in
                   (writer, churn, sampler, drainer, drainer,
                    scraper, scraper, scraper)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]
        # final drain picks up whatever the racing drains left behind
        lines = urllib.request.urlopen(
            ex.url + "/control_log", timeout=10).read().decode()
        drained.extend(json.loads(ln)["t"] for ln in lines.splitlines()
                       if "dropped" not in json.loads(ln))
        _assert_well_formed(urllib.request.urlopen(
            ex.url + "/metrics", timeout=10).read().decode())
    svc.stop()
    # exactly-once delivery across concurrent drains: no duplicates,
    # nothing invented, everything the writer appended accounted for
    assert len(drained) == len(set(drained))
    assert sorted(drained) == [float(i) for i in range(300)]
    assert arena.fragmentation() < 0.3 + 1e-9   # defrag actually ran


def test_pipeline_obs_requires_monitor():
    with pytest.raises(ValueError, match="monitor=False"):
        Pipeline([Stage("src", source=range(4)),
                  Stage("id", fn=lambda x: x)], capacity=8,
                 arena=CounterArena(8), monitor=False, obs=True)
    pipe = Pipeline([Stage("src", source=range(4)),
                     Stage("id", fn=lambda x: x)], capacity=8,
                    arena=CounterArena(8), obs=True)
    assert isinstance(pipe.exporter, MetricsExporter)
    assert pipe.exporter.service is pipe.fleet

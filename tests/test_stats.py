import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from scipy import stats as sstats

from repro.core.stats import (Moments, Welford, moments_finalize,
                              moments_init, moments_merge, moments_update,
                              welford_init, welford_merge, welford_std,
                              welford_update, welford_variance)


def _run_welford(xs):
    s = welford_init(jnp.float64 if False else jnp.float32)
    for x in xs:
        s = welford_update(s, x)
    return s


def test_welford_matches_numpy():
    rng = np.random.default_rng(1)
    xs = rng.normal(5.0, 2.0, 500).astype(np.float32)
    s = _run_welford(xs)
    assert float(s.mean) == pytest.approx(xs.mean(), rel=1e-4)
    assert float(welford_variance(s)) == pytest.approx(xs.var(), rel=1e-3)


def test_welford_merge_equals_concat():
    rng = np.random.default_rng(2)
    a = rng.normal(size=100).astype(np.float32)
    b = rng.normal(3.0, 1.5, 77).astype(np.float32)
    merged = welford_merge(_run_welford(a), _run_welford(b))
    full = _run_welford(np.concatenate([a, b]))
    assert float(merged.mean) == pytest.approx(float(full.mean), rel=1e-4)
    assert float(merged.m2) == pytest.approx(float(full.m2), rel=1e-3)


def test_welford_merge_empty_identity():
    s = _run_welford(np.arange(10, dtype=np.float32))
    m = welford_merge(s, welford_init())
    assert float(m.mean) == pytest.approx(float(s.mean))
    assert float(m.count) == 10


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=2,
                max_size=60),
       st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=2,
                max_size=60))
def test_welford_merge_commutative(a, b):
    sa, sb = _run_welford(np.float32(a)), _run_welford(np.float32(b))
    ab, ba = welford_merge(sa, sb), welford_merge(sb, sa)
    np.testing.assert_allclose(float(ab.mean), float(ba.mean),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(float(ab.m2), float(ba.m2),
                               rtol=1e-2, atol=1e-2)


def _run_moments(xs):
    s = moments_init()
    for x in xs:
        s = moments_update(s, x)
    return s


def test_moments_match_scipy():
    rng = np.random.default_rng(3)
    xs = rng.exponential(2.0, 2000).astype(np.float32)
    mean, var, skew, kurt, cv2 = moments_finalize(_run_moments(xs))
    assert float(mean) == pytest.approx(xs.mean(), rel=1e-3)
    assert float(var) == pytest.approx(xs.var(), rel=2e-2)
    assert float(skew) == pytest.approx(sstats.skew(xs), rel=0.1)
    assert float(kurt) == pytest.approx(sstats.kurtosis(xs), rel=0.25)
    # exponential: cv^2 ~ 1
    assert 0.8 < float(cv2) < 1.2


def test_moments_merge_equals_concat():
    rng = np.random.default_rng(4)
    a = rng.gamma(2.0, 1.0, 300).astype(np.float32)
    b = rng.gamma(3.0, 2.0, 200).astype(np.float32)
    merged = moments_merge(_run_moments(a), _run_moments(b))
    full = _run_moments(np.concatenate([a, b]))
    for f_m, f_f in zip(merged, full):
        assert float(f_m) == pytest.approx(float(f_f), rel=2e-2,
                                           abs=1e-2)

"""Self-healing control plane (repro.ft.inject + supervisor, hardened
ControlLoop) — PR 6.

Covers: crash containment in pipeline workers (recorded, STOP countdown
stays coherent), deterministic fault injection, supervisor detection +
respawn + crash-loop breaker (degraded stage -> `faulty` actuator
mask), the heartbeat-registry forget satellite, the control loop
surviving a raising actuator, sense-side NaN quarantine, the monitor
watchdog (estimator state survives the dead timer thread), the
`faulty` operand's decision semantics and no-retrace contract, and the
orphaned FT primitives (FaultToleranceManager / plan_elastic_mesh)
driven from the streams stack.
"""

import time
import threading

import numpy as np
import pytest

from repro.control import (BufferPolicy, ControlConfig, ControlLoop,
                           PolicySet, ReplicaPolicy, control_decide,
                           control_decide_trace_count, control_init)
from repro.core.monitor import MonitorConfig
from repro.ft import (FaultEvent, FaultPlan, FaultToleranceManager,
                      FaultyActuator, HeartbeatRegistry, InjectedFault,
                      ReplicaSupervisor)
from repro.streams import (CounterArena, FleetMonitorService,
                           InstrumentedQueue, Pipeline, Stage)

CFG = MonitorConfig(window=16, min_q_samples=16)


def _paced_source(n, dt=2e-4):
    for i in range(n):
        time.sleep(dt)
        yield i


# -- fault plan primitives -------------------------------------------------

def test_fault_plan_deterministic_and_unarmed_inert():
    a = FaultPlan.chaos(seed=7, targets=["work"], n_crashes=3,
                        monitor_death_at=1.0)
    b = FaultPlan.chaos(seed=7, targets=["work"], n_crashes=3,
                        monitor_death_at=1.0)
    assert ([(e.at_s, e.kind, e.target) for e in a._events]
            == [(e.at_s, e.kind, e.target) for e in b._events])
    # un-armed: nothing is ever due, nothing is consumed
    assert a.worker_fault_due("work") is None
    assert not a.monitor_death_due()
    assert a.pending() == 4


def test_fault_plan_consumes_once_and_audits():
    plan = FaultPlan([FaultEvent(0.0, "crash", "work"),
                      FaultEvent(0.0, "stall", "work", duration_s=0.01),
                      FaultEvent(0.0, "clock_skew", duration_s=10.0,
                                 factor=2.0)]).arm()
    with pytest.raises(InjectedFault):
        plan.maybe_fault("work#3", aliases=("work",))
    t0 = time.monotonic()
    plan.maybe_fault("work")          # the stall: sleeps ~10ms
    assert time.monotonic() - t0 >= 0.009
    plan.maybe_fault("work")          # drained: no-op
    assert plan.pending() == 1        # the skew window is not consumed
    assert plan.skew_factor() == pytest.approx(2.0)
    kinds = sorted(e.kind for _, e in plan.fired())
    assert kinds == ["crash", "stall"]


def test_faulty_actuator_injects_one_raise():
    class Inner:
        def scale(self, i, n):
            return "applied"
    act = FaultyActuator(Inner(), FaultPlan(
        [FaultEvent(0.0, "actuation", "scale")]).arm())
    with pytest.raises(InjectedFault):
        act.scale(0, 2)
    assert act.scale(0, 2) == "applied"      # one-shot


# -- satellite: heartbeat forget -------------------------------------------

def test_heartbeat_registry_forget():
    reg = HeartbeatRegistry(timeout_s=0.0)
    reg.beat("a")
    reg.beat("b")
    assert sorted(reg.dead_hosts(time.monotonic() + 1)) == ["a", "b"]
    reg.forget("a")
    assert reg.dead_hosts(time.monotonic() + 1) == ["b"]
    reg.forget("zzz")                 # unknown host: no-op


# -- satellite: crash containment ------------------------------------------

def test_worker_crash_recorded_and_stream_completes():
    """A consumer replica dying mid-item must be recorded in stats()
    (not silently vanish) and must not wedge the STOP countdown."""
    def boom(x):
        if x == 17:
            raise RuntimeError("kaboom")
        return x * 2

    pipe = Pipeline([Stage("src", source=range(200)),
                     Stage("work", fn=boom, replicas=2)],
                    capacity=16, arena=CounterArena(8))
    out = pipe.run_collect(timeout_s=60)
    st = pipe.stats()
    assert st["crash_count"] == 1
    (rec,) = st["crashes"]
    assert rec["stage"] == "work" and "kaboom" in rec["exc"]
    assert rec["worker"].startswith("work#")
    # the poisoned item is lost with its worker; everything else flows
    assert sorted(out) == [2 * i for i in range(200) if i != 17]
    assert pipe.live_replicas("work") == 1


def test_source_crash_ends_stream_with_stop():
    def bad_gen():
        yield 0
        yield 1
        raise RuntimeError("source died")

    pipe = Pipeline([Stage("src", source=bad_gen()),
                     Stage("work", fn=lambda x: x)],
                    capacity=8, arena=CounterArena(8))
    out = pipe.run_collect(timeout_s=30)
    assert sorted(out) == [0, 1]
    assert pipe.stats()["crash_count"] == 1


# -- supervisor: detect + respawn + breaker --------------------------------

def test_supervisor_respawns_crashed_replica():
    plan = FaultPlan([FaultEvent(0.02, "crash", "work")])
    pipe = Pipeline([Stage("src", source=_paced_source(1500)),
                     Stage("work", fn=lambda x: x, replicas=2)],
                    capacity=32, arena=CounterArena(8), fault_plan=plan)
    sup = ReplicaSupervisor(pipe, poll_s=0.005, backoff_base_s=0.005)
    sup.start()
    plan.arm()
    out = pipe.run_collect(timeout_s=120)
    sup.stop()
    assert pipe.stats()["crash_count"] == 1
    assert sup.respawns >= 1
    assert len(out) >= 1500 - 1           # only the in-flight item is lost
    assert pipe.live_replicas("work") == 2
    acts = [r.action for r in sup.log.records()
            if r.policy == "supervisor"]
    assert "crash" in acts and "respawn" in acts
    errs = [r.error for r in sup.log.records() if r.action == "crash"]
    assert "E_REPLICA_DEAD" in errs


def test_crash_loop_breaker_degrades_stage():
    """A stage that dies on every item trips the breaker: the zombie
    slots retire, no more replicas are fed in, the stage is marked
    degraded and the actuator reports its queue `faulty`."""
    def always(x):
        raise RuntimeError("crash loop")

    pipe = Pipeline([Stage("src", source=range(50)),
                     Stage("work", fn=always)],
                    capacity=8, arena=CounterArena(8))
    sup = ReplicaSupervisor(pipe, poll_s=0.002, backoff_base_s=0.001,
                            breaker_threshold=3, healthy_after_s=60.0)
    sup.start()
    t = threading.Thread(target=pipe.run_collect,
                         kwargs={"timeout_s": 20}, daemon=True)
    t.start()
    deadline = time.monotonic() + 15
    while "work" not in pipe._degraded and time.monotonic() < deadline:
        time.sleep(0.01)
    assert "work" in pipe._degraded
    assert sup.breaker_trips == 1
    _, act = pipe.control_tenant()
    assert act.faulty().tolist() == [True, False]   # work's queue, sink
    assert any(r.error == "E_CRASH_LOOP" for r in sup.log.records())
    assert pipe.stats()["crash_count"] >= 3
    assert pipe.live_replicas("work") == 0
    sup.stop()


# -- hardened control loop -------------------------------------------------

def _service(Q, chunk_t=16):
    arena = CounterArena(2 * Q)
    queues = [InstrumentedQueue(8, arena=arena) for _ in range(Q)]
    svc = FleetMonitorService(queues, CFG, period_s=1e-3, chunk_t=chunk_t,
                              scale_to_period=False, ends="both")
    return svc, queues


def _feed(svc, queues, head_tc, tail_tc, n):
    for _ in range(n):
        for q in queues:
            q.head.tc = float(head_tc)
            q.tail.tc = float(tail_tc)
        svc.sample()
    svc.flush()


class _RaisingActuator:
    """scale() always raises — an actuation path gone bad."""

    def __init__(self, q):
        self.q = q
        self.attempts = 0

    def replicas(self):
        return np.ones(self.q, np.int64)

    def capacities(self):
        return np.full(self.q, 64, np.int64)

    def occupancy(self):
        return np.zeros(self.q)

    def scale(self, i, n):
        self.attempts += 1
        raise RuntimeError("actuator wedged")

    def resize(self, i, cap):
        return "applied"

    def admit(self, i, shed):
        return "applied"


def test_loop_survives_raising_actuator_and_audits_error():
    svc, queues = _service(2)
    act = _RaisingActuator(2)
    loop = ControlLoop(svc, PolicySet(replica=ReplicaPolicy()), act,
                       actuation_retries=2, actuation_backoff_s=1e-4)
    _feed(svc, queues, head_tc=50.0, tail_tc=100.0, n=200)
    for _ in range(loop.cfg.confirm_ticks + 2):
        loop.tick()                   # must not raise
    assert act.attempts >= 3          # 1 try + 2 retries on first fire
    errs = [r for r in loop.log.records() if r.outcome == "error"]
    assert errs and all(r.error == "E_ACT_RAISE" for r in errs)
    assert loop.health()["actuation_errors"] >= 1


def test_admission_failure_rolls_back_gate_memory():
    """A failed admit() leaves the loop's shed memory at the last
    applied state so the flip is retried, not forgotten."""
    from repro.control import AdmissionPolicy
    from repro.control.policy import Decision
    svc, queues = _service(1)

    class BadAdmit(_RaisingActuator):
        def __init__(self, q):
            super().__init__(q)
            self.reverts = []

        def admit(self, i, shed):
            if not shed:               # the rollback revert is allowed
                self.reverts.append(i)
                return "applied"
            raise RuntimeError("gate wedged")

    act = BadAdmit(1)
    loop = ControlLoop(svc, PolicySet(admission=AdmissionPolicy()), act,
                       actuation_retries=0)
    z = np.zeros(1, np.int32)
    zb = np.zeros(1, bool)
    dec = Decision(target_replicas=z, scale_mask=zb, target_caps=z,
                   resize_mask=zb, shed=np.ones(1, bool), straggler=zb,
                   probing=zb, slo_hot=zb)
    loop._actuate(dec, np.zeros(1), np.zeros(1),
                  np.ones(1, np.int64), np.full(1, 64, np.int64))
    # the shed flip failed: memory stays False (retried next tick) and
    # the physical gate was reverted to the last applied state
    assert not loop._shed.any()
    assert act.reverts == [0]
    assert loop.health()["actuation_errors"] >= 1
    errs = [r for r in loop.log.records() if r.outcome == "error"]
    assert errs and errs[0].error == "E_ACT_RAISE"


def test_sense_nan_quarantine_falls_back_to_last_good():
    svc, queues = _service(2)
    act = _RaisingActuator(2)
    act.scale = lambda i, n: "applied"
    loop = ControlLoop(svc, PolicySet(replica=ReplicaPolicy()), act)
    _feed(svc, queues, head_tc=50.0, tail_tc=100.0, n=200)
    loop.tick()                        # establishes last-good estimates
    good_mu = loop._last_good_mu.copy()
    assert (good_mu > 0).all()
    orig = svc.gated_rates
    svc.gated_rates = lambda: np.full(4, np.nan)
    try:
        loop.tick()                    # must not poison the decision
    finally:
        svc.gated_rates = orig
    assert loop.quarantined == 4
    assert np.allclose(loop._last_good_mu, good_mu)
    recs = [r for r in loop.log.records() if r.error == "E_SENSE_NAN"]
    assert recs and recs[0].outcome == "observed"
    loop.tick()                        # healthy again
    assert loop.quarantined == 4       # no new quarantines


def test_watchdog_restarts_dead_monitor_preserving_estimator_state():
    plan = FaultPlan([FaultEvent(0.0, "monitor_death", "monitor")]).arm()
    pipe = Pipeline([Stage("src", source=range(10)),
                     Stage("work", fn=lambda x: x)],
                    capacity=8, arena=CounterArena(8), control=True,
                    monitor_cfg=CFG, fault_plan=plan)
    old = pipe.monitor
    svc = pipe.fleet
    old.start()
    old.join(timeout=10)               # injected silent death
    assert not old.is_alive() and not old._stop_evt.is_set()
    assert pipe.control.check_monitor()
    try:
        assert pipe.monitor is not old
        assert pipe.monitor.is_alive()
        assert pipe.fleet is svc       # estimator state survived
        assert pipe.control.health()["monitor_restarts"] == 1
        recs = [r for r in pipe.control.log.records()
                if r.policy == "watchdog"]
        assert recs and recs[0].error == "E_MONITOR_DEAD"
        assert not pipe.control.check_monitor()   # alive: no-op
    finally:
        pipe.monitor.stop()


def test_loop_run_contains_tick_errors():
    svc, queues = _service(1)
    loop = ControlLoop(svc, PolicySet(replica=ReplicaPolicy()),
                       _RaisingActuator(1), period_s=1e-3)
    svc.gated_rates = lambda: (_ for _ in ()).throw(RuntimeError("boom"))
    loop.start()
    time.sleep(0.05)
    loop.stop()
    h = loop.health()
    assert h["tick_errors"] >= 1
    assert any(r.error == "E_TICK" for r in loop.log.records())


# -- degraded-mode decision leg --------------------------------------------

def test_faulty_operand_holds_actions_and_sheds():
    cfg = ControlConfig(confirm_ticks=1, cooldown_ticks=0, min_ready=1)
    q = 2
    st = control_init(cfg, q)
    faulty = np.array([True, False])
    dec = None
    for _ in range(3):                 # past confirmation
        st, dec = control_decide(
            cfg, st, lam=np.full(q, 100.0), mu=np.full(q, 50.0),
            ready=np.ones(q, bool), replicas=np.ones(q),
            caps=np.full(q, 64), faulty=faulty, impl="numpy")
    assert not dec.scale_mask[0]       # replica action held
    assert dec.scale_mask[1]           # healthy neighbor unaffected
    assert dec.shed[0]                 # admission forced shut
    assert not dec.shed[1]


def test_faulty_operand_does_not_retrace():
    cfg = ControlConfig(confirm_ticks=1, block_q=16,
                        cooldown_ticks=11)          # fresh cache key

    def run(q, faulty):
        control_decide(cfg, control_init(cfg, q),
                       lam=np.full(q, 100.0), mu=np.full(q, 50.0),
                       ready=np.ones(q, bool), replicas=np.ones(q),
                       caps=np.full(q, 64), faulty=faulty,
                       impl="jit", donate=True)

    base = control_decide_trace_count()
    run(3, None)
    warm = control_decide_trace_count()
    assert warm > base
    for q, f in ((5, None), (3, np.array([True, False, True])),
                 (9, np.ones(9, bool)), (16, np.zeros(16, bool))):
        run(q, f)
    assert control_decide_trace_count() == warm


# -- orphaned FT primitives driven from the streams stack ------------------

def test_ft_manager_elastic_plan_from_supervised_pipeline():
    """FaultToleranceManager.assess over the supervisor's live registry
    and rate tracker: a lapsed replica host yields an ElasticPlan that
    names it."""
    pipe = Pipeline([Stage("src", source=_paced_source(800)),
                     Stage("work", fn=lambda x: x, replicas=2)],
                    capacity=32, arena=CounterArena(8))
    sup = ReplicaSupervisor(pipe, poll_s=0.005,
                            heartbeat_timeout_s=0.15)
    sup.start()
    pipe.run_collect(timeout_s=120)
    # the supervisor fed each replica's drained-item rate into the
    # Algorithm-1 host tracker while the stream ran
    assert any(h.startswith("work#") for h in sup.rates.monitors)
    ftm = FaultToleranceManager(n_hosts=8, chips_per_host=4,
                                heartbeat_timeout_s=0.15)
    ftm.heartbeats = sup.heartbeats    # the streams-stack registry
    ftm.rates = sup.rates
    victim = sorted(h for h in sup.heartbeats._last
                    if h.startswith("work#"))[0]
    time.sleep(0.2)                    # everything lapses...
    for h in list(sup.heartbeats._last):
        if h != victim:
            sup.heartbeats.beat(h)     # ...then all but the victim beat
    plan = ftm.assess(latest_ckpt_step=123)
    assert plan is not None
    assert victim in plan.dropped_hosts
    assert plan.restart_step == 123
    assert plan.n_chips < 8 * 4
    sup.stop()
    assert sup.heartbeats._last == {}  # stop() forgets every host


# -- engine bulkhead supervision (PR 7) ------------------------------------

from repro.control import AdmissionPolicy, ControlConfig as _CC
from repro.control import control_decide as _decide, control_init as _init
from repro.serve import BLOCKING, NONBLOCKING, Engine, Request, ServeConfig


class _SleepEngine(Engine):
    """Model-free engine whose serve round just burns a little time."""

    def _serve_batch(self, batch):
        time.sleep(2e-3)
        for r in batch:
            r.out = np.zeros(1, np.int32)
            r.done.set()
            self.served += 1


def _breq(i):
    return Request(rid=i, tokens=np.arange(4), max_new=1, qos=BLOCKING)


def _wait(pred, timeout=15.0):
    deadline = time.monotonic() + timeout
    while not pred() and time.monotonic() < deadline:
        time.sleep(0.01)
    return pred()


def test_supervisor_respawns_borrowed_replica_into_own_bulkhead():
    """A seeded plan kills the patient-lane worker while it is borrowed
    into the blocking lane mid-spike: the supervisor must respawn it
    into the NONBLOCKING partition (borrowed capacity never migrates),
    the crash record must carry the class, and the spike still
    completes."""
    plan = FaultPlan([FaultEvent(0.05, "crash", NONBLOCKING)])
    eng = _SleepEngine(None, None,
                       ServeConfig(batch_size=2, queue_capacity=64,
                                   bulkheads=(1, 1)),
                       arena=CounterArena(4), fault_plan=plan)
    sup = ReplicaSupervisor(engines=[eng], poll_s=0.01)
    eng.start()
    sup.start()
    plan.arm()
    try:
        reqs = [_breq(i) for i in range(60)]     # the blocking spike
        for r in reqs:
            assert eng.submit(r, timeout=10)
        assert _wait(lambda: len(plan.fired()) == 1)
        assert _wait(lambda: sup.respawns >= 1)
        # the replacement landed in the patient partition
        sizes = eng.bulkhead_sizes()
        assert sizes == {BLOCKING: 1, NONBLOCKING: 1}
        live_nb = [w for w in eng.workers() if w.qos == NONBLOCKING
                   and w.is_alive()]
        assert live_nb and f":{NONBLOCKING}#" in live_nb[0].host
        for r in reqs:
            assert r.done.wait(timeout=30)
        crash = [r for r in sup.log.records() if r.action == "crash"]
        assert crash and crash[0].qos == NONBLOCKING
        assert crash[0].error == "E_ENGINE_DEAD"
        resp = [r for r in sup.log.records() if r.action == "respawn"]
        assert resp and resp[0].qos == NONBLOCKING
    finally:
        sup.stop()
        eng.stop()


def test_engine_bulkhead_breaker_degrades_class_and_recovers():
    """A crash-looping bulkhead trips its (engine, class) breaker: the
    class is marked degraded, the actuator's ``faulty`` lane mask makes
    the fused decision shut that lane's gate, and a clean healthy
    window recovers the partition (replicas refilled)."""
    plan = FaultPlan([FaultEvent(0.0, "crash", NONBLOCKING),
                      FaultEvent(0.0, "crash", NONBLOCKING)])
    eng = _SleepEngine(None, None,
                       ServeConfig(batch_size=2, queue_capacity=16,
                                   bulkheads=(1, 1)),
                       arena=CounterArena(4), fault_plan=plan)
    sup = ReplicaSupervisor(engines=[eng], poll_s=0.01,
                            breaker_threshold=2, healthy_after_s=0.3)
    eng.start()
    sup.start()
    plan.arm()
    try:
        assert _wait(lambda: NONBLOCKING in eng._degraded)
        assert sup.breaker_trips == 1
        assert eng.bulkhead_sizes()[NONBLOCKING] == 0
        assert eng.bulkhead_sizes()[BLOCKING] == 1
        # the faulty operand's decision semantics on the lane mask
        mask = eng._actuator.faulty()
        assert mask.tolist() == [False, True]
        cfg = _CC(confirm_ticks=1, cooldown_ticks=0, min_ready=1)
        st = _init(cfg, 2)
        dec = None
        for _ in range(2):
            st, dec = _decide(
                cfg, st, lam=np.full(2, 100.0), mu=np.full(2, 100.0),
                ready=np.ones(2, bool), replicas=np.ones(2),
                caps=np.full(2, 64), faulty=mask, impl="numpy")
        assert dec.shed.tolist() == [False, True]
        assert not dec.scale_mask[1]             # legs held, not re-tuned
        assert any(r.error == "E_CRASH_LOOP" and r.qos == NONBLOCKING
                   for r in sup.log.records())
        # healthy window: breaker resets, partition refills
        assert _wait(lambda: NONBLOCKING not in eng._degraded, timeout=20)
        assert _wait(
            lambda: eng.bulkhead_sizes()[NONBLOCKING] == 1, timeout=20)
        assert eng._actuator.faulty().tolist() == [False, False]
        assert any(r.action == "recovered" and r.qos == NONBLOCKING
                   for r in sup.log.records())
    finally:
        sup.stop()
        eng.stop()

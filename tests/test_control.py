"""Closed-loop elastic actuation (repro.control) — PR 4.

Covers the fused decision step's gating state machine (pre-convergence
quiescence, confirmation/hysteresis on noisy signals, cooldown,
admission arm/disarm), advisory/actuation agreement, the no-retrace
contract for ragged fleets, live stage-worker scaling (spawn + retire
draining without loss), rejected-shrink retry, stop()/flush() safety
mid-actuation, and the engine admission gate.
"""

import threading
import time

import numpy as np
import pytest

from repro.control import (AdmissionPolicy, BufferPolicy, ControlConfig,
                           ControlGroup, ControlLog, ControlLoop,
                           ControlRecord, PolicySet, ReplicaPolicy,
                           control_decide, control_decide_trace_count,
                           control_init)
from repro.core.monitor import MonitorConfig
from repro.streams import (CounterArena, FleetMonitorService,
                           FleetMonitorThread, InstrumentedQueue,
                           MonitorThread, Pipeline, Stage)

CFG = MonitorConfig(window=16, min_q_samples=16)


class _FakeActuator:
    """Records every actuation; outcomes are scriptable per-call."""

    def __init__(self, q, caps=64, reps=1):
        self.reps = np.full(q, reps, np.int64)
        self.caps = np.full(q, caps, np.int64)
        self.occ = np.zeros(q)
        self.calls = []
        self.resize_outcome = "applied"

    def replicas(self):
        return self.reps.copy()

    def capacities(self):
        return self.caps.copy()

    def occupancy(self):
        return self.occ

    def scale(self, i, n):
        self.calls.append(("scale", i, n))
        self.reps[i] = n
        return "applied"

    def resize(self, i, cap):
        self.calls.append(("resize", i, cap))
        if self.resize_outcome == "applied":
            self.caps[i] = cap
        return self.resize_outcome

    def admit(self, i, shed):
        self.calls.append(("admit", i, shed))
        return "applied"


def _service(Q, chunk_t=16):
    arena = CounterArena(2 * Q)
    queues = [InstrumentedQueue(8, arena=arena) for _ in range(Q)]
    svc = FleetMonitorService(queues, CFG, period_s=1e-3, chunk_t=chunk_t,
                              scale_to_period=False, ends="both")
    return svc, queues


def _feed(svc, queues, head_tc, tail_tc, n):
    """Replay n constant-rate periods through the batched collector."""
    for _ in range(n):
        for q in queues:
            q.head.tc = float(head_tc)
            q.tail.tc = float(tail_tc)
        svc.sample()
    svc.flush()


def test_pre_convergence_gate_no_actuation():
    """Edge case 1: before the Welford-count readiness gate opens, the
    loop must not actuate — a handful of q-folds is a raw sample."""
    svc, queues = _service(3)
    act = _FakeActuator(3)
    loop = ControlLoop(svc, PolicySet(replica=ReplicaPolicy(),
                                      buffer=BufferPolicy()), act)
    _feed(svc, queues, head_tc=50.0, tail_tc=100.0, n=8)   # < min_q_samples
    for _ in range(4):
        loop.tick()
    assert act.calls == []
    assert len(loop.log) == 0


def test_replica_scaling_actuates_after_confirmation():
    """A converged 2x overload scales the consumer stage after
    confirm_ticks agreeing decisions, and the decision is audited."""
    svc, queues = _service(2)
    act = _FakeActuator(2)
    loop = ControlLoop(svc, PolicySet(replica=ReplicaPolicy()), act)
    _feed(svc, queues, head_tc=50.0, tail_tc=100.0, n=200)
    assert (svc.gated_rates() > 0).all()
    for _ in range(loop.cfg.confirm_ticks + 1):
        loop.tick()
    scales = [c for c in act.calls if c[0] == "scale"]
    assert scales, "overloaded stages must be scaled"
    # ceil(1.2 * 100/50) = 3 replicas
    assert all(c[2] == 3 for c in scales)
    recs = loop.log.by_policy("replicas")
    assert recs and recs[0].outcome == "applied" and recs[0].value == 3


def test_hysteresis_prevents_oscillation_on_noisy_signal():
    """Edge case 2: a rate signal oscillating across a replica boundary
    every tick never accumulates confirm_ticks agreeing decisions, so
    the loop holds still instead of thrashing scale up/down."""
    cfg = ControlConfig(confirm_ticks=2, cooldown_ticks=2, block_q=8)
    state = control_init(cfg, 1)
    fired = 0
    for t in range(40):
        # aggregate mu at 2 live replicas: per-copy mu/2, so the target
        # ceil(1.2*120/(mu/2)) = ceil(288/mu) flips 3 <-> 2 every tick
        mu = 100.0 if t % 2 == 0 else 150.0
        state, dec = control_decide(
            cfg, state, lam=[120.0], mu=[mu], ready=[True],
            replicas=[2], caps=[64], donate=True)
        fired += int(np.asarray(dec.scale_mask)[0]
                     and int(np.asarray(dec.target_replicas)[0]) != 2)
    assert fired == 0

    # the same config DOES act on a persistent signal
    state = control_init(cfg, 1)
    fired = 0
    for _ in range(6):
        state, dec = control_decide(
            cfg, state, lam=[120.0], mu=[45.0], ready=[True],
            replicas=[2], caps=[64], donate=True)
        fired += int(np.asarray(dec.scale_mask)[0])
    assert fired >= 1


def test_cooldown_spaces_consecutive_actuations():
    """After an actuation the queue rests cooldown_ticks even though the
    (changing) signal keeps confirming new targets every tick."""
    cfg = ControlConfig(confirm_ticks=1, cooldown_ticks=4, block_q=8)
    state = control_init(cfg, 1)
    reps, fire_ticks = 1, []
    for t in range(12):
        # aggregate mu making ceil(1.2*lam*reps/mu) land on reps+1:
        # the signal always wants one replica more than we have
        mu = 1.2 * 100.0 * reps / (reps + 0.5)
        state, dec = control_decide(
            cfg, state, lam=[100.0], mu=[mu], ready=[True],
            replicas=[reps], caps=[64], donate=True)
        if bool(np.asarray(dec.scale_mask)[0]):
            fire_ticks.append(t)
            reps = int(np.asarray(dec.target_replicas)[0])
    assert len(fire_ticks) >= 2
    gaps = np.diff(fire_ticks)
    assert (gaps >= cfg.cooldown_ticks).all()


def test_admission_arm_disarm_state_machine():
    """Admission leg: a collapsed-rate + hot-queue stream sheds, and the
    gate reopens only through the recovery hysteresis."""
    cfg = ControlConfig(confirm_ticks=1, block_q=8, min_ready=4)
    Q = 6
    state = control_init(cfg, Q)
    lam = np.full(Q, 100.0)
    mu = np.full(Q, 100.0)
    occ = np.full(Q, 0.2)

    def tick():
        nonlocal state
        state, dec = control_decide(
            cfg, state, lam=lam, mu=mu, ready=np.ones(Q, bool),
            replicas=np.ones(Q), caps=np.full(Q, 64), occupancy=occ,
            donate=True)
        return np.asarray(dec.shed), np.asarray(dec.straggler)

    for _ in range(4):                  # build the peak at healthy rate
        shed, _ = tick()
    assert not shed.any()

    mu[3] = 20.0                        # queue 3 collapses...
    occ[3] = 0.95                       # ...while its queue runs hot
    shed, straggler = tick()
    assert shed[3] and not shed[[0, 1, 2, 4, 5]].any()
    assert straggler[3]                 # below fleet-median threshold too

    occ[3] = 0.8                        # still above occupancy_lo...
    shed, _ = tick()
    assert shed[3]                      # ...gate stays shut (hysteresis)

    mu[3] = 100.0                       # service recovers
    shed, _ = tick()
    assert not shed[3]


def test_advice_equals_actuation_targets():
    """Satellite: the fused decision's targets are the very numbers the
    advisory policy objects report — advice cannot disagree."""
    rng = np.random.default_rng(5)
    Q = 17
    lam = rng.uniform(10, 500, Q)
    mu = rng.uniform(10, 500, Q)
    cv2 = rng.uniform(0.2, 2.0, Q)
    caps = rng.integers(4, 256, Q)
    rep_pol, buf_pol = ReplicaPolicy(), BufferPolicy()
    ps = PolicySet(replica=rep_pol, buffer=buf_pol, block_q=32)
    cfg = ps.control_config()
    _, dec = control_decide(
        cfg, control_init(cfg, Q), lam=lam, mu=mu,
        ready=np.ones(Q, bool), replicas=np.ones(Q), caps=caps, cv2=cv2,
        donate=True)
    np.testing.assert_array_equal(np.asarray(dec.target_replicas),
                                  rep_pol.targets(lam, mu))
    np.testing.assert_array_equal(np.asarray(dec.target_caps),
                                  buf_pol.targets(lam, mu, caps, cv2))


def test_pipeline_advisory_delegates_to_policy():
    pipe = Pipeline([Stage("src", source=range(10)),
                     Stage("id", fn=lambda x: x)], capacity=8)
    lam = pipe.fleet.arrival_rates()
    mu = pipe.fleet.service_rates()
    want = pipe.replica_policy.targets(lam, mu)
    got = pipe.recommended_replicas()
    assert got == {"id": int(want[0])}


def test_ragged_fleets_share_one_decision_trace():
    """The jitted decision form (the accelerator contract) pads the
    queue axis to block_q, so ragged fleet sizes never retrace."""
    cfg = ControlConfig(confirm_ticks=1, block_q=16,
                        cooldown_ticks=7)          # fresh cache key
    def run(q):
        control_decide(cfg, control_init(cfg, q),
                       lam=np.full(q, 100.0), mu=np.full(q, 50.0),
                       ready=np.ones(q, bool), replicas=np.ones(q),
                       caps=np.full(q, 64), impl="jit", donate=True)
    base = control_decide_trace_count()
    run(3)
    warm = control_decide_trace_count()
    assert warm > base
    for q in (5, 9, 16, 2, 11):
        run(q)
    assert control_decide_trace_count() == warm


def test_numpy_and_jit_decision_forms_agree():
    """The host numpy fast path and the jitted dispatch execute the same
    ``_step_math`` source — every decision and every state leaf must
    match over a random driven sequence."""
    rng = np.random.default_rng(3)
    cfg = ControlConfig(confirm_ticks=2, cooldown_ticks=3, block_q=16,
                        min_ready=4)
    Q = 13
    st_n, st_j = control_init(cfg, Q), control_init(cfg, Q)
    for t in range(40):
        ops = dict(lam=rng.uniform(0, 300, Q), mu=rng.uniform(0, 300, Q),
                   ready=rng.random(Q) > 0.2,
                   replicas=rng.integers(1, 8, Q),
                   caps=rng.integers(4, 256, Q),
                   cv2=rng.uniform(0.1, 2, Q), occupancy=rng.random(Q),
                   saturated=rng.random(Q) > 0.8,
                   stale=rng.random(Q) > 0.8,
                   leg_rep=rng.random(Q) > 0.2,
                   leg_buf=rng.random(Q) > 0.2,
                   leg_adm=rng.random(Q) > 0.2,
                   headroom=rng.uniform(1.0, 2.0, Q),
                   max_replicas=rng.integers(2, 16, Q))
        st_n, dn = control_decide(cfg, st_n, impl="numpy", **ops)
        st_j, dj = control_decide(cfg, st_j, impl="jit", donate=False,
                                  **ops)
        for name, a, b in zip(dn._fields, dn, dj):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"tick {t} {name}")
        for name, a, b in zip(st_n._fields, st_n, st_j):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6,
                                       err_msg=f"tick {t} state {name}")


def test_saturation_escalates_when_demand_unobservable():
    """A queue whose producer end blocks persistently has unobservable
    demand (lam gated to 0): the loop must still scale — multiplicative
    escalation until the queue unblocks — instead of sitting quiet on a
    dark signal."""
    cfg = ControlConfig(confirm_ticks=2, cooldown_ticks=0, block_q=8,
                        saturation_growth=2.0, max_replicas=16)
    state = control_init(cfg, 1)
    reps = 2
    for _ in range(3):
        state, dec = control_decide(
            cfg, state, lam=[0.0], mu=[120.0], ready=[True],
            replicas=[reps], caps=[64], saturated=[True], donate=True)
        if bool(np.asarray(dec.scale_mask)[0]):
            reps = int(np.asarray(dec.target_replicas)[0])
    assert reps == 4                    # 2 -> ceil(2 * 2.0)
    # without the saturation flag the same dark signal does nothing
    state = control_init(cfg, 1)
    for _ in range(4):
        state, dec = control_decide(
            cfg, state, lam=[0.0], mu=[120.0], ready=[True],
            replicas=[2], caps=[64], saturated=[False], donate=True)
        assert not np.asarray(dec.scale_mask)[0]


def test_rejected_shrink_is_logged_and_retried():
    """Edge case 3: a shrink the queue refuses (items still queued) is
    recorded as rejected and retried after the cooldown, succeeding
    once the queue drained."""
    svc, queues = _service(1)
    act = _FakeActuator(1, caps=64)
    act.resize_outcome = "rejected"
    ps = PolicySet(buffer=BufferPolicy(), confirm_ticks=1,
                   cooldown_ticks=2)
    loop = ControlLoop(svc, ps, act)
    # converged low-traffic rates: tiny recommended capacity
    _feed(svc, queues, head_tc=100.0, tail_tc=50.0, n=200)
    for _ in range(3):
        loop.tick()
    rej = [r for r in loop.log.by_policy("capacity")
           if r.outcome == "rejected"]
    assert rej, "refused shrink must be audited"
    assert act.caps[0] == 64            # capacity unchanged
    act.resize_outcome = "applied"      # queue drained
    for _ in range(2 + ps.cooldown_ticks):
        loop.tick()
    applied = [r for r in loop.log.by_policy("capacity")
               if r.outcome == "applied"]
    assert applied
    assert act.caps[0] == applied[-1].value < 64


def test_queue_shrink_below_occupancy_refused_live():
    """The actuator honors the queue's never-drop contract: a shrink
    below the queued item count returns rejected and keeps capacity."""
    q = InstrumentedQueue(16, arena=CounterArena(4))
    for i in range(12):
        q.push(i)
    assert q.resize(8) is False
    assert q.capacity == 16
    for _ in range(8):
        q.pop()
    assert q.resize(8) is True
    assert [q.pop() for _ in range(4)] == [8, 9, 10, 11]


def test_scale_down_cannot_close_monitored_queue():
    """Edge case: monitored (pinned) ends refuse release while the
    service lives — scale-down retires workers, never the queue —
    and close() works after FleetMonitorService.stop() unpins."""
    svc, queues = _service(2)
    with pytest.raises(ValueError, match="monitors"):
        queues[0].close()
    svc.stop()
    queues[0].close()                   # unpinned now: slot recycles


def test_live_scale_up_and_retire_drain_without_loss():
    """Edge case 4: spawn extra workers mid-run, then retire most of
    them mid-run; every item is processed exactly once."""
    N = 6000
    pipe = Pipeline([Stage("src", source=range(N)),
                     Stage("work", fn=lambda x: x * 2, replicas=3)],
                    capacity=32, arena=CounterArena(16))
    got = {"ok": False}

    def driver():
        time.sleep(0.05)
        assert pipe.scale_stage("work", 5) == "applied"
        time.sleep(0.05)
        assert pipe.scale_stage(1, 1) == "applied"
        got["ok"] = True

    t = threading.Thread(target=driver, daemon=True)
    t.start()
    out = pipe.run_collect(timeout_s=120)
    t.join(timeout=10)
    assert got["ok"]
    assert sorted(out) == [2 * i for i in range(N)]
    assert pipe.live_replicas("work") == 1


def test_scale_stage_guards():
    pipe = Pipeline([Stage("src", source=range(4)),
                     Stage("id", fn=lambda x: x)], capacity=8,
                    arena=CounterArena(8))
    assert pipe.scale_stage("src", 2) == "rejected"   # source stage
    assert pipe.scale_stage("id", 0) == "rejected"    # n < 1
    assert pipe.scale_stage("id", 1) == "noop"        # already there
    assert pipe.scale_stage("id", 4) == "applied"     # pre-start intent
    assert pipe.live_replicas("id") == 4
    out = pipe.run_collect(timeout_s=60)
    assert sorted(out) == list(range(4))


def test_closed_loop_pipeline_runs_end_to_end():
    """A control=True pipeline runs the full sense->decide->actuate
    stack live (loop thread + fused decision + actuator adapter) and
    still produces exact results."""
    pipe = Pipeline([Stage("src", source=range(3000)),
                     Stage("x3", fn=lambda x: x * 3)], capacity=64,
                    base_period_s=1e-3, control=True, monitor_cfg=CFG)
    assert pipe.autotune is False       # the loop owns actuation
    out = pipe.run_collect(timeout_s=120)
    assert sorted(out) == [3 * i for i in range(3000)]
    # every audited decision carries a real outcome
    assert all(r.outcome in ("applied", "rejected", "noop")
               for r in pipe.control.log)


def test_stop_flush_safe_during_actuation():
    """Bugfix satellite: FleetMonitorService.stop()/flush() must be
    callable while a control tick is mid-actuation — lock ordering
    guarantees interleaving, not deadlock."""
    svc, queues = _service(2)

    class _SlowActuator(_FakeActuator):
        def resize(self, i, cap):
            time.sleep(2e-3)            # hold the actuation window open
            return super().resize(i, cap)

    act = _SlowActuator(2, caps=64)
    loop = ControlLoop(svc, PolicySet(buffer=BufferPolicy(),
                                      confirm_ticks=1, cooldown_ticks=0),
                       act)
    _feed(svc, queues, head_tc=100.0, tail_tc=50.0, n=200)

    stop_err = []

    def hammer():
        try:
            for _ in range(50):
                svc.flush()
                time.sleep(5e-4)
            svc.stop()
        except Exception as e:          # noqa: BLE001
            stop_err.append(e)

    t = threading.Thread(target=hammer, daemon=True)
    t.start()
    for _ in range(30):
        loop.tick()
    t.join(timeout=30)
    assert not t.is_alive() and not stop_err
    assert svc.sample() is False        # quiesced, not crashed


def test_control_log_ring_wraps():
    log = ControlLog(capacity=4)
    for k in range(10):
        log.append(ControlRecord(tick=k, t=0.0, queue=0, policy="replicas",
                                 observed_lam=1.0, observed_mu=1.0,
                                 action="scale", value=k,
                                 outcome="applied"))
    assert len(log) == 4 and log.total == 10
    assert [r.value for r in log.records()] == [6, 7, 8, 9]
    assert log.tail(2)[-1].value == 9
    assert log.counts() == {"replicas/applied": 4}


def test_engine_admission_gate_shed_and_defer():
    from repro.serve.engine import AdmissionGate

    g = AdmissionGate("shed")
    assert g.allow(1.0)
    g.set_shed(True)
    assert g.shedding and not g.allow(1.0)
    g.set_shed(False)
    assert g.allow(1.0) and g.shed_count == 1

    g = AdmissionGate("defer")
    g.set_shed(True)
    t0 = time.monotonic()
    assert not g.allow(0.05)            # waited, then timed out
    assert time.monotonic() - t0 >= 0.04

    def reopen():
        time.sleep(0.02)
        g.set_shed(False)
    threading.Thread(target=reopen, daemon=True).start()
    assert g.allow(2.0)                 # deferred submit goes through
    assert g.defer_count == 2


def test_loop_period_rederives_from_adapting_service():
    """Bugfix satellite: FleetMonitorThread adapts service.period_s
    every tick, so a derived loop period must track it live instead of
    freezing the construction-time value — and an explicit period must
    stay fixed."""
    svc, _ = _service(1)
    loop = ControlLoop(svc, PolicySet(replica=ReplicaPolicy()),
                       _FakeActuator(1))
    assert loop._current_period() == pytest.approx(
        svc.period_s * svc.chunk_t)
    svc.period_s *= 8                    # the thread's adaptive T widened
    assert loop._current_period() == pytest.approx(
        svc.period_s * svc.chunk_t)
    assert loop.period_s == pytest.approx(svc.period_s * svc.chunk_t)

    fixed = ControlLoop(svc, PolicySet(replica=ReplicaPolicy()),
                        _FakeActuator(1), period_s=0.5)
    svc.period_s *= 2
    assert fixed._current_period() == 0.5

    # the run() thread survives a live period change (smoke)
    svc.period_s = 1e-4
    loop.start()
    time.sleep(0.05)
    svc.period_s = 1e-3
    time.sleep(0.02)
    loop.stop()
    assert not loop.is_alive() and loop.ticks >= 1


def test_monitor_threads_stop_join_before_flush():
    """Bugfix satellite: both monitor-thread stop() paths must join the
    thread (so a final in-flight sample cannot race the flush) instead
    of only setting the event."""
    svc, _ = _service(2)
    th = FleetMonitorThread(svc)
    th.start()
    time.sleep(0.03)
    th.stop()                            # join + flush
    assert not th.is_alive()
    th.stop()                            # idempotent

    arena = CounterArena(4)
    q = InstrumentedQueue(8, arena=arena)
    from repro.streams import QueueMonitor
    mt = MonitorThread([QueueMonitor(q)])
    mt.start()
    time.sleep(0.02)
    mt.stop()
    assert not mt.is_alive()


def test_monitor_thread_fires_on_tail_only_convergence():
    """Bugfix satellite: a tail-only convergence (arrival-rate epoch
    advance) must fire on_converged — previously only the head epoch
    was checked and tail convergences were silently dropped."""
    class _E:
        epoch = 0

    class _P:
        period_s = 1e-3

    class _FakeQM:
        def __init__(self):
            self.head, self.tail = _E(), _E()
            self.period = _P()
            self._last_t = 0.0
            self.samples = 0

        def sample(self):
            self._last_t = time.monotonic()
            self.samples += 1
            if self.samples == 2:
                self.tail = type("E", (), {"epoch": 1})()  # tail-only

    fired = threading.Event()
    qm = _FakeQM()
    mt = MonitorThread([qm], on_converged=lambda m: fired.set())
    mt.start()
    assert fired.wait(5.0), "tail-only convergence must fire on_converged"
    mt.stop()


def test_control_loop_senses_head_only_service():
    """The ends='head' sense path (no arrival leg: lam.shape[0] == 0)
    must tick cleanly — demand stays dark, so neither the replica nor
    the capacity leg may fire, and saturation never escalates."""
    arena = CounterArena(8)
    queues = [InstrumentedQueue(8, arena=arena) for _ in range(2)]
    svc = FleetMonitorService(queues, CFG, period_s=1e-3, chunk_t=16,
                              scale_to_period=False, ends="head")
    act = _FakeActuator(2)
    loop = ControlLoop(svc, PolicySet(replica=ReplicaPolicy(),
                                      buffer=BufferPolicy(),
                                      confirm_ticks=1, cooldown_ticks=0),
                       act)
    for _ in range(200):
        for q in queues:
            q.head.tc = 50.0
        svc.sample()
    svc.flush()
    assert (svc.gated_rates() > 0).all()     # heads converged...
    for _ in range(6):
        dec = loop.tick()
        assert not np.asarray(dec.scale_mask).any()
        assert not np.asarray(dec.probing).any()
    assert act.calls == []                   # ...but demand is dark
    svc.stop()


# -- demand probe: scale-down for the escalated / stale regime -------------

def test_probe_decays_escalated_replicas_no_ratchet():
    """Acceptance: after an AIMD saturation escalation, a demand drop
    is detected and replicas decay back to within 1 step of the
    hand-tuned oracle within N = log2(overshoot) probe windows — the
    escalation is no longer a ratchet."""
    cfg = ControlConfig(confirm_ticks=1, cooldown_ticks=0, block_q=8,
                        saturation_growth=2.0, max_replicas=16,
                        probe_period_ticks=3, probe_window_ticks=2)
    state = control_init(cfg, 1)
    reps = 2
    while reps < 8:                     # saturation escalates 2 -> 8
        state, dec = control_decide(
            cfg, state, lam=[0.0], mu=[120.0], ready=[True],
            replicas=[reps], caps=[64], saturated=[True], donate=True)
        if np.asarray(dec.scale_mask)[0]:
            reps = int(np.asarray(dec.target_replicas)[0])
    assert reps == 8
    # demand dies: the frozen arrival estimate reads stale-high (the
    # loop senses this as the window mean collapsing under the gated
    # estimate and passes stale=True)
    oracle, windows, ticks = 1, 0, 0
    cycle = cfg.probe_period_ticks + cfg.probe_window_ticks
    while reps > oracle + 1 and ticks < 8 * cycle:
        state, dec = control_decide(
            cfg, state, lam=[100.0], mu=[120.0], ready=[True],
            replicas=[reps], caps=[64], stale=[True], donate=True)
        ticks += 1
        if np.asarray(dec.scale_mask)[0]:
            reps = int(np.asarray(dec.target_replicas)[0])
            windows += 1
    assert reps <= oracle + 1            # within 1 step of the oracle
    assert windows <= 3                  # 8 -> 4 -> 2: one per window
    assert ticks <= 3 * cycle + 3


def test_probe_window_reopens_shed_gate_and_aborts_on_demand():
    """The probe window forces a shed gate open so hidden demand can
    show itself; a window that re-saturates (demand is real) aborts the
    cycle without decaying, one that stays dark decays."""
    cfg = ControlConfig(confirm_ticks=1, cooldown_ticks=0, block_q=8,
                        probe_period_ticks=2, probe_window_ticks=2,
                        max_replicas=4, min_ready=1)
    state = control_init(cfg, 1)

    def tick(**kw):
        nonlocal state
        ops = dict(lam=[100.0], mu=[20.0], ready=[True], replicas=[4],
                   caps=[64], occupancy=[0.95], donate=True)
        ops.update(kw)
        state, dec = control_decide(cfg, state, **ops)
        return dec

    for _ in range(4):                  # build peak, then collapse+hot
        tick(mu=[100.0], occupancy=[0.2])
    dec = tick()
    assert np.asarray(dec.shed)[0]      # armed: collapsed + hot queue
    # stale demand: probe cycle runs while the gate stays armed
    seen_open = False
    for _ in range(2 * (cfg.probe_period_ticks
                        + cfg.probe_window_ticks)):
        dec = tick(stale=[True])
        p, s = (np.asarray(dec.probing)[0], np.asarray(dec.shed)[0])
        if p:
            assert not s                # window forces the gate open
            seen_open = True
        decayed = np.asarray(dec.scale_mask)[0]
        if decayed:
            break
    assert seen_open and decayed
    assert int(np.asarray(dec.target_replicas)[0]) == 2

    # a probe that re-saturates (real demand flooded back) aborts:
    # no decay fires while saturation holds
    state = control_init(cfg, 1)
    for _ in range(4):
        tick(mu=[100.0], occupancy=[0.2])
    for _ in range(3):
        dec = tick(stale=[True])        # timer runs toward the window
    dec = tick(stale=[True], saturated=[True])
    assert not np.asarray(dec.probing)[0]
    assert not np.asarray(dec.scale_mask)[0] \
        or int(np.asarray(dec.target_replicas)[0]) >= 4


def test_probe_end_to_end_through_service_staleness():
    """Loop-level probe: rates converge through the real service, then
    the stream goes quiet — the gated arrival estimate freezes high,
    the window mean collapses, the loop's staleness sense kicks in and
    the probe decays the (over-provisioned) replicas, no ratchet."""
    svc, queues = _service(1)
    act = _FakeActuator(1, reps=8)      # provision left over from a surge
    ps = PolicySet(replica=ReplicaPolicy(), confirm_ticks=1,
                   cooldown_ticks=0, probe_period_ticks=2,
                   probe_window_ticks=2)
    loop = ControlLoop(svc, ps, act)
    _feed(svc, queues, head_tc=120.0, tail_tc=100.0, n=200)
    assert (svc.gated_rates() > 0).all()
    q = queues[0]
    decayed = []
    for t in range(40):
        for _ in range(16):             # demand dead: consumer starves,
            q.head.tc = 0.0             # producer folds zero samples
            q.head.blocked = True
            q.tail.tc = 0.0
            q.tail.blocked = False
            svc.sample()
        loop.tick()
        if act.reps[0] <= 2:
            break
    assert act.reps[0] <= 2, "stale demand must decay escalated replicas"
    scales = [c for c in act.calls if c[0] == "scale"]
    assert scales and scales[-1][2] <= 2


# -- multi-tenant control plane (ControlGroup) -----------------------------

def _raw_tenant(arena, n, caps=64, reps=1):
    queues = [InstrumentedQueue(8, arena=arena) for _ in range(n)]
    return queues, _FakeActuator(n, caps=caps, reps=reps)


def test_group_attach_detach_keeps_decision_trace_flat():
    """Acceptance: ragged tenant churn (attach/detach of different
    sizes) under impl='jit' never retraces the decision dispatch —
    per-tenant differences ride as operands, and the queue axis pads
    to one block_q multiple."""
    arena = CounterArena(32)
    group = ControlGroup(
        PolicySet(replica=ReplicaPolicy(), block_q=8, confirm_ticks=3,
                  cooldown_ticks=6),     # distinct knobs: own cache key
        arena=arena, monitor_cfg=CFG, period_s=1e-3, chunk_t=8,
        scale_to_period=False, impl="jit")
    h1 = group.attach(_raw_tenant(arena, 2), name="t1")
    group.tick()
    warm = control_decide_trace_count()
    h2 = group.attach(_raw_tenant(arena, 3), name="t2")
    group.tick()
    group.detach(h1)
    group.tick()
    group.attach(_raw_tenant(arena, 1), name="t3")
    group.tick()
    group.detach(h2)
    group.tick()
    assert control_decide_trace_count() == warm
    group.service.stop()


def test_group_remap_preserves_tenant_gating_state():
    """Detaching one tenant must not reset another's loop state: a
    half-built confirmation counter carries across the restructure and
    fires on schedule, not one tick late."""
    arena = CounterArena(16)
    group = ControlGroup(
        PolicySet(replica=ReplicaPolicy(), confirm_ticks=2,
                  cooldown_ticks=0, block_q=8),
        arena=arena, monitor_cfg=CFG, period_s=1e-3, chunk_t=4,
        scale_to_period=False)
    qa, acta = _raw_tenant(arena, 1)
    qb, actb = _raw_tenant(arena, 1)
    ha = group.attach((qa, acta), name="a")
    hb = group.attach((qb, actb), name="b")
    # converge tenant b at 2x overload (3-replica target)
    for _ in range(200):
        qa[0].head.tc = qa[0].tail.tc = 50.0
        qb[0].head.tc, qb[0].tail.tc = 50.0, 100.0
        group.service.sample()
    group.service.flush()
    group.tick()                         # b: rep_agree = 1 (of 2)
    assert not [c for c in actb.calls if c[0] == "scale"]
    group.detach(ha)                     # restructure mid-confirmation
    group.tick()                         # b: rep_agree = 2 -> fires now
    scales = [c for c in actb.calls if c[0] == "scale"]
    assert scales == [("scale", 0, 3)]
    group.service.stop()


def test_control_group_spans_pipelines_and_engine():
    """Integration: two monitor=False pipelines + one monitor=False
    engine share one arena and one ControlGroup; items flow exactly,
    advisory readouts ride the bound tenant views, the engine's
    admission gate is actuated through the composite, and detached
    tenants can close their queues."""
    from repro.serve import Engine, ServeConfig

    class _Cfg:
        vocab_size = 16

    class _FakeModel:
        cfg = _Cfg()

        def prefill(self, params, batch):
            raise NotImplementedError

        def decode_step(self, params, cache, tok, pos):
            raise NotImplementedError

    arena = CounterArena(32)
    group = ControlGroup(
        PolicySet(replica=ReplicaPolicy(), buffer=BufferPolicy(),
                  admission=AdmissionPolicy(), block_q=8),
        arena=arena, monitor_cfg=CFG, period_s=1e-3, chunk_t=8)
    pa = Pipeline([Stage("srcA", source=range(2000)),
                   Stage("wA", fn=lambda x: x * 2)], capacity=32,
                  arena=arena, monitor=False)
    pb = Pipeline([Stage("srcB", source=range(1000)),
                   Stage("wB", fn=lambda x: x + 1)], capacity=32,
                  arena=arena, monitor=False)
    eng = Engine(_FakeModel(), None, ServeConfig(queue_capacity=8),
                 arena=arena, monitor=False)
    with pytest.raises(RuntimeError, match="externally monitored"):
        pa.rates()
    group.attach(pa, name="A")
    group.attach(pb, name="B")
    h_eng = group.attach(eng, policies=PolicySet(
        buffer=BufferPolicy(), admission=AdmissionPolicy()),
        name="engine")
    group.start()
    out_a = pa.run_collect(timeout_s=120)
    out_b = pb.run_collect(timeout_s=120)
    assert sorted(out_a) == [2 * i for i in range(2000)]
    assert sorted(out_b) == [i + 1 for i in range(1000)]
    # advisory readouts ride the sliced tenant views
    assert set(pa.rates()) == {"srcA->wA", "wA->sink"}
    assert isinstance(pa.recommended_replicas(), dict)
    assert eng.service_rate() >= 0.0
    # the composite routes admission to the engine's gate
    eng_idx = len(pa.queues) + len(pb.queues)
    assert group.actuator.admit(eng_idx, True) == "applied"
    assert eng.gate.shedding
    group.actuator.admit(eng_idx, False)
    # every audited decision carries a real outcome
    assert all(r.outcome in ("applied", "rejected", "noop")
               for r in group.log)
    group.detach(h_eng)
    with pytest.raises(RuntimeError, match="externally monitored"):
        eng.service_rate()               # view unbound on detach
    group.stop()
    eng.queue.close()                    # detached + stopped: unpinned


def test_group_rejects_leg_outside_superset():
    group = ControlGroup(PolicySet(replica=ReplicaPolicy(), block_q=8),
                         arena=CounterArena(8), monitor_cfg=CFG)
    with pytest.raises(ValueError, match="superset"):
        group.attach(_raw_tenant(group.arena, 1),
                     policies=PolicySet(admission=AdmissionPolicy()))


def test_group_rejects_divergent_gating_knobs():
    """Gating/probe knobs live in the ONE shared ControlConfig: a
    tenant PolicySet asking for different (non-default) values must be
    rejected, not silently overridden by the group's."""
    group = ControlGroup(PolicySet(replica=ReplicaPolicy(), block_q=8,
                                   probe_period_ticks=6),
                         arena=CounterArena(8), monitor_cfg=CFG)
    with pytest.raises(ValueError, match="group-wide"):
        group.attach(_raw_tenant(group.arena, 1),
                     policies=PolicySet(replica=ReplicaPolicy(),
                                        probe_period_ticks=50))
    # defaults read as unspecified; matching values are fine
    group.attach(_raw_tenant(group.arena, 1),
                 policies=PolicySet(replica=ReplicaPolicy(),
                                    probe_period_ticks=6))
    group.service.stop()


def test_restructure_translates_convergence_emits():
    """Emits harvested during a restructure carry post-restructure
    stream indices (detached streams' emits are dropped) — consumers
    resolve them against the new fleet."""
    arena = CounterArena(16)
    queues = [InstrumentedQueue(8, arena=arena) for _ in range(2)]
    got = []
    # chunk_t larger than the feed: every sample stays staged, so the
    # first convergences are dispatched+harvested BY the restructure
    svc = FleetMonitorService(
        queues, CFG, period_s=1e-3, chunk_t=256, scale_to_period=False,
        ends="both", on_fleet=lambda idx, rates: got.append(idx.copy()))
    for _ in range(200):
        for q in queues:
            q.head.tc = q.tail.tc = 50.0
        svc.sample()
    assert not got                       # nothing dispatched yet
    svc.detach([queues[0]])              # restructure fires the emits
    assert got, "staged convergences must still be delivered"
    seen = np.concatenate(got)
    # queue 1's streams were old indices (1, 3); after the detach they
    # are (0, 1) — delivered translated, detached streams dropped
    assert set(seen.tolist()) == {0, 1}
    svc.stop()


def test_group_rejects_double_attach():
    """Attaching an already-monitored queue would gather it into two
    staging rows (double-counting every rate) and a later detach of one
    alias would desync the other — the service must refuse."""
    arena = CounterArena(8)
    group = ControlGroup(PolicySet(replica=ReplicaPolicy(), block_q=8),
                         arena=arena, monitor_cfg=CFG)
    tenant = _raw_tenant(arena, 1)
    group.attach(tenant, name="t")
    with pytest.raises(ValueError, match="already monitored"):
        group.attach(tenant, name="t-again")
    assert len(group.tenants()) == 1     # failed attach left no residue
    assert group.loop.n_queues == 1
    group.service.stop()


def test_group_rejects_self_monitoring_tenant():
    """A tenant that still owns its own monitor (default monitor=True)
    would double-collect the shared arena cells — both collectors
    copy-and-zero the same counters and each silently reads ~half the
    true rates — so attach must refuse it."""
    arena = CounterArena(16)
    group = ControlGroup(PolicySet(replica=ReplicaPolicy(), block_q=8),
                         arena=arena, monitor_cfg=CFG)
    pipe = Pipeline([Stage("src", source=range(4)),
                     Stage("id", fn=lambda x: x)], capacity=8,
                    arena=arena)            # monitor=True: self-owned
    with pytest.raises(ValueError, match="monitor=False"):
        group.attach(pipe)
    pipe.fleet.stop()


def test_engine_control_loop_sheds_submits():
    """serve.Engine + control=True: a shut gate makes submit() reject
    immediately; reopening admits again.  (Gate transitions are driven
    directly — the collapse scenario itself is exercised in the
    control benchmark's scenario suite.)"""
    from repro.serve import Engine, Request, ServeConfig

    class _Cfg:
        vocab_size = 16

    class _FakeModel:
        cfg = _Cfg()

        def prefill(self, params, batch):
            raise NotImplementedError

        def decode_step(self, params, cache, tok, pos):
            raise NotImplementedError

    eng = Engine(_FakeModel(), None,
                 ServeConfig(batch_size=2, max_seq=32, queue_capacity=8),
                 control=True)
    assert eng.control is not None
    assert eng.admission_state()["shedding"] is False
    req = Request(rid=0, tokens=np.zeros(4, np.int32))
    assert eng.submit(req)
    eng.gate.set_shed(True)
    assert not eng.submit(Request(rid=1, tokens=np.zeros(4, np.int32)))
    assert eng.admission_state()["shed_count"] == 1
    eng.gate.set_shed(False)
    assert eng.submit(Request(rid=2, tokens=np.zeros(4, np.int32)))
    # capacity advice delegates to the loop's own BufferPolicy
    assert eng.recommended_queue_capacity() == 8


# -- PR 9: SLO burn-rate leg (latency-aware scaling) -------------------------


def _slo_cfg(**kw):
    base = dict(confirm_ticks=1, cooldown_ticks=1, block_q=8,
                slo_enabled=True, slo_fast_ticks=2, slo_slow_ticks=4,
                max_replicas=16)
    base.update(kw)
    return ControlConfig(**base)


def test_slo_burn_escalates_on_latency_alone_and_impl_parity():
    """Tentpole: with throughput balanced (rate formula satisfied), a
    sustained over-SLO window alone must escalate replicas
    multiplicatively — and the jit and numpy forms of the same
    ``_step_math`` must agree bit-for-bit on the decisions and closely
    on the burn EMAs, with at most one fresh trace."""
    cfg = _slo_cfg()
    results = {}
    for impl in ("numpy", "jit"):
        state = control_init(cfg, 1)
        t0 = control_decide_trace_count()
        targets, burns, hots = [], [], []
        for _ in range(8):
            state, dec = control_decide(
                cfg, state, lam=[100.0], mu=[150.0], ready=[True],
                replicas=[2], caps=[64], slo_target=[4e-3],
                over_frac=[1.0], impl=impl, donate=False)
            targets.append(int(np.asarray(dec.target_replicas)[0])
                           if np.asarray(dec.scale_mask)[0] else 0)
            hots.append(bool(np.asarray(dec.slo_hot)[0]))
            burns.append((float(np.asarray(state.burn_fast)[0]),
                          float(np.asarray(state.burn_slow)[0])))
        if impl == "jit":
            assert control_decide_trace_count() - t0 <= 1
        results[impl] = (targets, hots, burns)
    targets, hots, burns = results["numpy"]
    # formula is quiet (ceil(1.2*100*2/150) == 2 == live replicas), so
    # every scale decision is the SLO leg's multiplicative escalation
    fired = [t for t in targets if t]
    assert fired and all(t == 4 for t in fired)       # 2 * saturation_growth
    assert any(hots)
    assert results["jit"][0] == targets
    assert results["jit"][1] == hots
    np.testing.assert_allclose(results["jit"][2], burns, rtol=1e-5)

    # contrast: same traffic, within-SLO windows -> the leg stays cold
    state = control_init(cfg, 1)
    for _ in range(8):
        state, dec = control_decide(
            cfg, state, lam=[100.0], mu=[150.0], ready=[True],
            replicas=[2], caps=[64], slo_target=[4e-3], over_frac=[0.0],
            impl="numpy", donate=False)
        assert not np.asarray(dec.scale_mask)[0]
        assert not np.asarray(dec.slo_hot)[0]


def test_nan_slo_target_never_escalates():
    """A queue with no SLO (NaN target) must decide exactly like the
    pre-SLO path no matter what over_frac claims: zero burn, never
    hot."""
    cfg = _slo_cfg()
    state = control_init(cfg, 1)
    for _ in range(6):
        state, dec = control_decide(
            cfg, state, lam=[100.0], mu=[150.0], ready=[True],
            replicas=[2], caps=[64], slo_target=[np.nan], over_frac=[1.0],
            impl="numpy", donate=False)
        assert not np.asarray(dec.scale_mask)[0]
        assert not np.asarray(dec.slo_hot)[0]
        assert float(np.asarray(state.burn_fast)[0]) == 0.0
        assert float(np.asarray(state.burn_slow)[0]) == 0.0


def test_slo_cooldown_holds_then_steps_down_one_notch():
    """After a burn episode the slow window must freeze scale-down
    (handing capacity straight back would re-ignite the violation),
    then release into ONE multiplicative notch per confirmed step —
    16 -> 8 -> 4 -> 2 — never a snap to the latency-blind formula."""
    cfg = _slo_cfg(slo_slow_ticks=8)

    def run(slo_target):
        state = control_init(cfg, 1)
        reps, downs, first_down = 16, [], None
        for t in range(60):
            over = 1.0 if t < 3 else 0.0
            # mu = 100*reps keeps the formula target pinned at 2:
            # ceil(1.2 * 100 * reps / (100 * reps)) == 2
            state, dec = control_decide(
                cfg, state, lam=[100.0], mu=[100.0 * reps],
                ready=[True], replicas=[reps], caps=[64],
                slo_target=[slo_target], over_frac=[over],
                impl="numpy", donate=False)
            if np.asarray(dec.scale_mask)[0]:
                tgt = int(np.asarray(dec.target_replicas)[0])
                if tgt < reps:
                    downs.append(tgt)
                    if first_down is None:
                        first_down = t
                reps = tgt               # actuate
        return downs, first_down

    downs, first_down = run(slo_target=4e-3)
    assert downs == [8, 4, 2]            # one notch per confirmed step
    assert first_down is not None and first_down > 10   # slow-window hold

    # contrast: no SLO armed -> the formula snaps straight down
    downs, first_down = run(slo_target=np.nan)
    assert downs[:1] == [2]
    assert first_down <= 2


def test_empty_window_burn_decays_and_releases():
    """NaN over_frac (nothing served) folds as zero budget consumption:
    the burn EMAs decay instead of pinning, and slo_hot releases once
    the fast window cools below slo_burn_lo."""
    cfg = _slo_cfg()
    state = control_init(cfg, 1)
    kw = dict(lam=[100.0], mu=[1600.0], ready=[True], replicas=[16],
              caps=[64], slo_target=[4e-3], impl="numpy", donate=False)
    for _ in range(3):
        state, dec = control_decide(cfg, state, over_frac=[1.0], **kw)
    assert np.asarray(dec.slo_hot)[0]
    bf = float(np.asarray(state.burn_fast)[0])
    assert bf > cfg.slo_burn_hi
    for _ in range(16):
        state, dec = control_decide(cfg, state, over_frac=[np.nan], **kw)
        nbf = float(np.asarray(state.burn_fast)[0])
        assert nbf < bf
        bf = nbf
    assert not np.asarray(dec.slo_hot)[0]
    assert bf < cfg.slo_burn_lo

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.train import (OptConfig, TrainConfig, clip_by_global_norm,
                         init_opt_state, lr_schedule, make_train_step,
                         opt_update, pick_optimizer)


def _setup(opt_name="adamw", microbatches=1):
    cfg = get_smoke_config("internlm2-1.8b")
    model = build_model(cfg)
    tcfg = TrainConfig(opt=OptConfig(name=opt_name, lr_peak=1e-2,
                                     warmup_steps=5, total_steps=100),
                       remat_policy=None, microbatches=microbatches)
    step = jax.jit(make_train_step(model, tcfg))
    params = model.init_params(jax.random.PRNGKey(0))
    state = {"params": params,
             "opt": init_opt_state(opt_name, params),
             "step": jnp.zeros((), jnp.int32)}
    return cfg, step, state


def _batch(cfg, key, B=4, S=16):
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def test_loss_decreases():
    cfg, step, state = _setup()
    batch = _batch(cfg, jax.random.PRNGKey(1))
    losses = []
    for _ in range(30):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.8
    assert int(state["step"]) == 30


def test_adamw8bit_tracks_adamw():
    cfg, step_a, state_a = _setup("adamw")
    _, step_q, state_q = _setup("adamw8bit")
    batch = _batch(cfg, jax.random.PRNGKey(2))
    for _ in range(10):
        state_a, ma = step_a(state_a, batch)
        state_q, mq = step_q(state_q, batch)
    # same trajectory within quantization noise
    assert float(mq["loss"]) == pytest.approx(float(ma["loss"]), rel=0.05)


def test_grad_accum_matches_full_batch_grads():
    cfg, _, state = _setup()
    model = build_model(cfg)
    batch = _batch(cfg, jax.random.PRNGKey(3), B=8)
    loss_fn = lambda p, b: model.loss(p, b)[0]        # noqa: E731
    g_full = jax.grad(loss_fn)(state["params"], batch)
    mbs = jax.tree_util.tree_map(
        lambda x: x.reshape((2, 4) + x.shape[1:]), batch)
    g_acc = jax.tree_util.tree_map(jnp.zeros_like, g_full)
    for i in range(2):
        mb = jax.tree_util.tree_map(lambda x: x[i], mbs)
        g = jax.grad(loss_fn)(state["params"], mb)
        g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
    g_acc = jax.tree_util.tree_map(lambda x: x / 2, g_acc)
    la, lb = jax.tree_util.tree_leaves(g_full), \
        jax.tree_util.tree_leaves(g_acc)
    for a, b in zip(la, lb):
        np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-4)


def test_clip_by_global_norm():
    grads = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, gn = clip_by_global_norm(grads, 1.0)
    assert float(gn) == pytest.approx(np.sqrt(90 + 160))
    total = np.sqrt(sum(float(jnp.sum(v ** 2))
                        for v in clipped.values()))
    assert total == pytest.approx(1.0, rel=1e-5)


def test_lr_schedule_shape():
    cfg = OptConfig(lr_peak=1e-3, lr_min=1e-4, warmup_steps=10,
                    total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in
           (0, 9, 10, 50, 100)]
    assert lrs[0] < lrs[1] <= cfg.lr_peak * 1.001
    assert lrs[2] == pytest.approx(cfg.lr_peak, rel=1e-2)
    assert lrs[-1] == pytest.approx(cfg.lr_min, rel=1e-2)
    assert lrs[3] < lrs[2]


def test_pick_optimizer():
    assert pick_optimizer(int(3e9)) == "adamw"
    assert pick_optimizer(int(314e9)) == "adamw8bit"

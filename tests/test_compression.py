import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np

from repro.dist.compression import dequantize_int8, quantize_int8


def test_int8_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32))
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    # error <= half a quantization step per row
    step = np.asarray(s)[:, None] if np.asarray(s).ndim else float(s)
    assert np.max(np.abs(np.asarray(back - x)) - 0.5 * step) <= 1e-6


def test_zero_rows_survive():
    x = jnp.zeros((4, 16))
    q, s = quantize_int8(x)
    np.testing.assert_array_equal(np.asarray(dequantize_int8(q, s)), 0.0)


_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.dist.compression import ef_compress_grads

    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()[:4]).reshape(4), ("pod",))
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))}
    r = {"w": jnp.zeros((8, 32), jnp.float32)}
    with mesh:
        red, res = ef_compress_grads(g, r, mesh, axis_name="pod")
    # identical replicated grads -> mean == original, within int8 error
    err = float(jnp.max(jnp.abs(red["w"] - g["w"])))
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert err <= scale + 1e-6, (err, scale)
    # error feedback holds the quantization residual
    assert float(jnp.max(jnp.abs(res["w"]))) <= scale + 1e-6
    print("COMPRESS_OK", err)
""")


def test_ef_compressed_allreduce_cross_pod():
    r = subprocess.run([sys.executable, "-c", _PROG],
                       capture_output=True, text=True, timeout=300)
    assert "COMPRESS_OK" in r.stdout, (r.stdout[-500:], r.stderr[-1500:])

"""Per-kernel shape/dtype sweeps asserting allclose vs the pure-jnp
oracles (interpret=True executes the Pallas kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention.kernel import flash_attention_pallas
from repro.kernels.attention.ref import attention_ref
from repro.kernels.monitor.kernel import batched_monitor_pallas
from repro.kernels.monitor.ref import batched_monitor_ref
from repro.kernels.ssd.ops import ssd_chunked_pallas
from repro.models.ssm import ssd_reference


@pytest.mark.parametrize("q,w", [(8, 16), (100, 32), (256, 64), (37, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_monitor_kernel_matches_ref(q, w, dtype):
    key = jax.random.PRNGKey(q * w)
    win = (jax.random.uniform(key, (q, w), jnp.float32) * 500).astype(
        dtype)
    qp, mup, sdp = batched_monitor_pallas(win, interpret=True)
    qr, mur, sdr = batched_monitor_ref(win)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(qp, qr, rtol=tol, atol=tol * 500)
    np.testing.assert_allclose(mup, mur, rtol=tol, atol=tol * 500)


@pytest.mark.parametrize("shape", [(1, 32, 2, 8, 8), (2, 64, 4, 8, 16),
                                   (2, 128, 2, 16, 32)])
@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_ssd_kernel_matches_sequential_reference(shape, chunk):
    B, S, H, P, N = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y_ref, h_ref = ssd_reference(x, dt, A, Bm, Cm)
    y, h = ssd_chunked_pallas(x, dt, A, Bm, Cm, chunk=chunk,
                              interpret=True)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h, h_ref, rtol=1e-4, atol=1e-4)


def test_ssd_kernel_state_carry():
    """Chunked-with-h0 must continue a previous segment exactly."""
    B, S, H, P, N = 1, 64, 2, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y_full, h_full = ssd_chunked_pallas(x, dt, A, Bm, Cm, chunk=16,
                                        interpret=True)
    y1, h1 = ssd_chunked_pallas(x[:, :32], dt[:, :32], A, Bm[:, :32],
                                Cm[:, :32], chunk=16, interpret=True)
    y2, h2 = ssd_chunked_pallas(x[:, 32:], dt[:, 32:], A, Bm[:, 32:],
                                Cm[:, 32:], chunk=16, h0=h1,
                                interpret=True)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h2, h_full, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(1, 128, 2, 2, 32), (2, 256, 4, 2, 32),
                                   (1, 256, 8, 8, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(shape, causal):
    B, S, H, K, hd = shape
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=causal, block_q=64,
                                 block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16_inputs(dtype):
    B, S, H, K, hd = 1, 128, 2, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    out = flash_attention_pallas(q.astype(dtype), k.astype(dtype),
                                 v.astype(dtype), interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=5e-2, atol=5e-2)

import textwrap

import pytest

from repro.configs import SHAPES, get_config
from repro.roofline.analysis import (CollectiveStats, model_flops,
                                     parse_collective_bytes,
                                     roofline_report)
from repro.roofline.analytic import analytic_bytes, analytic_flops
from repro.roofline.hlo import parse_collectives_hierarchical

_HLO = textwrap.dedent("""
    HloModule jit_f

    %cond.1 (arg.1: (s32[], f32[64,256])) -> pred[] {
      %p = (s32[], f32[64,256]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %c = s32[] constant(24)
      ROOT %lt = pred[] compare(%i, %c), direction=LT
    }

    %body.1 (arg.2: (s32[], f32[64,256])) -> (s32[], f32[64,256]) {
      %p = (s32[], f32[64,256]) parameter(0)
      %x = f32[64,256]{1,0} get-tuple-element(%p), index=1
      %ar = f32[64,256]{1,0} all-reduce(f32[64,256]{1,0} %x), to_apply=%sum
      ROOT %t = (s32[], f32[64,256]) tuple(%i, %ar)
    }

    ENTRY %main.1 (a: f32[64,256]) -> f32[64,256] {
      %a = f32[64,256]{1,0} parameter(0)
      %ag = f32[128,256]{1,0} all-gather(f32[64,256]{1,0} %a), dimensions={0}
      %w = (s32[], f32[64,256]) while((s32[], f32[64,256]) %t0), condition=%cond.1, body=%body.1
      ROOT %out = f32[64,256]{1,0} get-tuple-element(%w), index=1
    }
""")


def test_flat_parse_counts_each_once():
    st = parse_collective_bytes(_HLO)
    assert st.count_by_op == {"all-reduce": 1, "all-gather": 1}
    # all-reduce 64*256*4 * 2.0 mult; all-gather counts operand or result
    assert st.bytes_by_op["all-reduce"] == 64 * 256 * 4 * 2.0


def test_hierarchical_parse_multiplies_by_trip_count():
    st = parse_collectives_hierarchical(_HLO, default_trip=1)
    assert st.count_by_op["all-reduce"] == 24     # constant(24) in cond
    assert st.count_by_op["all-gather"] == 1
    assert st.bytes_by_op["all-reduce"] == 24 * 64 * 256 * 4 * 2.0


def test_model_flops_conventions():
    assert model_flops(1000, 10, "train") == 6000 * 10
    assert model_flops(1000, 10, "decode") == 2000 * 10


def test_roofline_report_dominant_term():
    coll = CollectiveStats({"all-reduce": 50e9}, {"all-reduce": 4})
    rep = roofline_report(flops_per_dev=197e12, bytes_per_dev=819e9,
                          coll=coll, n_chips=256,
                          model_flops_total=197e12 * 256)
    assert rep["compute_s"] == pytest.approx(1.0)
    assert rep["memory_s"] == pytest.approx(1.0)
    assert rep["collective_s"] == pytest.approx(1.0)
    assert rep["roofline_fraction"] == pytest.approx(1.0)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "grok-1-314b",
                                  "mamba2-2.7b", "whisper-large-v3"])
def test_analytic_flops_scale_with_model(arch):
    cfg = get_config(arch)
    tr = analytic_flops(cfg, SHAPES["train_4k"])
    pf = analytic_flops(cfg, SHAPES["prefill_32k"])
    # train fwd ~ 2*N*D: within 3x of the parameter-count estimate
    # (attention/router overheads push it above)
    est = 2.0 * cfg.n_active_params() * SHAPES["train_4k"].global_batch \
        * SHAPES["train_4k"].seq_len
    assert tr["forward"] == pytest.approx(est, rel=3.0)
    assert tr["forward"] > 0.5 * est
    assert tr["compiled"] == pytest.approx(tr["forward"] * 4.0)
    assert pf["compiled"] == pytest.approx(pf["forward"])


def test_analytic_bytes_decode_includes_cache():
    cfg = get_config("qwen2-vl-72b")
    ab = analytic_bytes(cfg, SHAPES["decode_32k"])
    # KV cache: 80L * 2 * B*S*K*hd * 2B
    exp_cache = 2 * 80 * 128 * 32768 * 8 * 128 * 2
    assert ab["cache_bytes"] == pytest.approx(exp_cache)
    assert ab["traffic"] > ab["cache_bytes"]

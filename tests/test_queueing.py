import numpy as np
import pytest

from repro.core.queueing import (expected_nonblocking_fraction,
                                 mm1k_blocking_prob, mm1k_throughput,
                                 optimal_buffer_size, pr_nonblocking_read,
                                 pr_nonblocking_write)
from repro.core.simulate import TandemConfig, simulate_tandem


def test_pr_read_is_rho_pow_k():
    # k = ceil(mu*T); Pr = rho^k  (Eq. 1b/1c)
    assert float(pr_nonblocking_read(T=1.0, rho=0.9, mu_s=5.0)) == \
        pytest.approx(0.9 ** 5)
    assert float(pr_nonblocking_read(T=0.7, rho=0.5, mu_s=10.0)) == \
        pytest.approx(0.5 ** 7)


def test_pr_read_decreases_with_T_and_mu():
    # Fig. 4: faster servers / longer windows are harder to observe
    ts = np.linspace(0.1, 2.0, 8)
    ps = [float(pr_nonblocking_read(t, 0.8, 4.0)) for t in ts]
    assert all(a >= b for a, b in zip(ps, ps[1:]))
    mus = np.linspace(1.0, 16.0, 8)
    ps = [float(pr_nonblocking_read(1.0, 0.8, m)) for m in mus]
    assert all(a >= b for a, b in zip(ps, ps[1:]))


def test_pr_write_zero_when_capacity_too_small():
    # Eq. 1d: C < mu*T => 0
    assert float(pr_nonblocking_write(T=1.0, C=3, rho=0.5, mu_s=5.0)) == 0.0
    assert float(pr_nonblocking_write(T=1.0, C=8, rho=0.5, mu_s=5.0)) == \
        pytest.approx(1.0 - 0.5 ** (8 - 5 + 1))


def test_mm1k_blocking_closed_form():
    # K=1 (single slot): P_block = rho/(1+rho)
    lam, mu = 2.0, 4.0
    rho = lam / mu
    assert float(mm1k_blocking_prob(lam, mu, 1)) == \
        pytest.approx(rho * (1 - rho) / (1 - rho ** 2))
    # rho = 1 limit: 1/(K+1)
    assert float(mm1k_blocking_prob(3.0, 3.0, 4)) == pytest.approx(0.2)


def test_mm1k_throughput_matches_simulation():
    cfg = TandemConfig(mu_a=4.0e5, mu_b=5.0e5, capacity=4,
                       n_items=120_000, seed=5)
    res = simulate_tandem(cfg)
    sim_thr = cfg.n_items / res.finish_t[-1]
    model_thr = float(mm1k_throughput(cfg.mu_a, cfg.mu_b, cfg.capacity))
    assert sim_thr == pytest.approx(model_thr, rel=0.1)


def test_optimal_buffer_size_monotone_and_effective():
    k90 = optimal_buffer_size(9.0e5, 1.0e6, target_frac=0.90)
    k99 = optimal_buffer_size(9.0e5, 1.0e6, target_frac=0.99)
    assert k99 >= k90 >= 1
    thr = float(mm1k_throughput(9.0e5, 1.0e6, k99))
    assert thr >= 0.99 * 9.0e5


def test_md1_needs_smaller_buffer_than_mm1():
    km = optimal_buffer_size(9e5, 1e6, target_frac=0.99, cv2=1.0)
    kd = optimal_buffer_size(9e5, 1e6, target_frac=0.99, cv2=0.0)
    assert kd <= km


def test_expected_nonblocking_fraction_bounds():
    f = expected_nonblocking_fraction(1e-3, 64, 0.5, 2.0e5)
    assert 0.0 <= f <= 1.0

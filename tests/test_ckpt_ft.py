import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.ft import (FaultToleranceManager, HeartbeatRegistry,
                      plan_elastic_mesh)


def _state(step=0):
    return {"params": {"w": jnp.arange(12, dtype=jnp.float32)
                       .reshape(3, 4),
                       "b": jnp.ones((4,), jnp.float32) * step},
            "step": jnp.asarray(step, jnp.int32)}


def test_ckpt_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state(7)
    mgr.save(7, s, blocking=True)
    restored, step = mgr.restore(_state())
    assert step == 7
    np.testing.assert_array_equal(restored["params"]["w"],
                                  s["params"]["w"])
    np.testing.assert_array_equal(restored["params"]["b"],
                                  s["params"]["b"])


def test_ckpt_auto_resume_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, _state(step), blocking=True)
    assert mgr.steps() == [3, 4]          # gc keeps last 2
    _, step = mgr.restore(_state())
    assert step == 4


def test_ckpt_corruption_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state(1), blocking=True)
    leaf = next((tmp_path / "step_1").glob("leaf_0.npy"))
    arr = np.load(leaf)
    arr_corrupt = arr.copy()
    arr_corrupt.flat[0] += 1
    np.save(leaf, arr_corrupt)
    with pytest.raises(IOError, match="corrupt"):
        mgr.restore(_state())


def test_ckpt_crash_mid_write_is_invisible(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _state(5), blocking=True)
    # simulate a crashed partial write: tmp dir left behind
    (tmp_path / ".tmp_step_6").mkdir()
    (tmp_path / ".tmp_step_6" / "leaf_0.npy").write_bytes(b"garbage")
    assert mgr.latest_step() == 5          # tmp dirs never count
    _, step = mgr.restore(_state())
    assert step == 5


def test_heartbeat_dead_host():
    hb = HeartbeatRegistry(timeout_s=0.05)
    hb.beat("a")
    hb.beat("b")
    time.sleep(0.08)
    hb.beat("b")
    assert hb.dead_hosts() == ["a"]
    assert hb.alive() == ["b"]


def test_elastic_plan_shrinks_data_axis():
    plan = plan_elastic_mesh(256, failed_chips=16)
    assert plan.new_shape == (15, 16)
    assert plan.n_chips == 240
    plan2 = plan_elastic_mesh(256, failed_chips=0)
    assert plan2.new_shape == (16, 16)


def test_ft_manager_detects_straggler_and_plans():
    ftm = FaultToleranceManager(n_hosts=8, chips_per_host=4,
                                heartbeat_timeout_s=100.0)
    for h in range(8):
        ftm.heartbeats.beat(f"host{h}")
    # hosts 0-6 run at 10 steps/s; host7 at 5 -> straggler
    rng = np.random.default_rng(0)
    for _ in range(600):
        for h in range(8):
            rate = 5.0 if h == 7 else 10.0
            ftm.rates.record_steps(f"host{h}",
                                   rng.poisson(rate), 1.0)
    plan = ftm.assess(latest_ckpt_step=123)
    assert plan is not None
    assert "host7" in plan.dropped_hosts
    assert plan.restart_step == 123
    assert plan.n_chips < 32

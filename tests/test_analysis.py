"""Contract analyzer (repro.analysis) — PR 10.

Seeded known-bad fixtures prove every checker fires on the violation
class it owns (LO001-LO003, LG001-LG004, BR001-BR002, RS001-RS003,
ST101-ST102), the runtime LockWitness catches inversions / ordered
re-entry / unordered-tier ABBA cycles on real ``threading`` locks,
the baseline workflow round-trips (new / baselined / stale), and the
shipped tree itself is clean against the shipped (empty) baseline —
the same gate ``scripts/smoke.sh`` runs.
"""

import os
import textwrap
import threading

import pytest

from repro.analysis import (ALL_CHECKERS, Baseline, LayerGuard,
                            LockOrderChecker, BenignRaceChecker,
                            RetraceSentinel, StylePass, run_analysis)
from repro.analysis.__main__ import DEFAULT_BASELINE, main
from repro.analysis.lock_order import classify_expr, classify_site
from repro.analysis.model import Source
from repro.analysis.witness import LockWitness, WitnessedLock

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src", "repro")


def _src(rel, code):
    return Source("<test>", rel, textwrap.dedent(code))


def _codes(checker, rel, code):
    return [f.code for f in checker.check(_src(rel, code))]


# ---------------------------------------------------------------------------
# LockOrderChecker
# ---------------------------------------------------------------------------

class TestLockOrderChecker:
    def test_declared_order_is_clean(self):
        code = """
        def tick(self):
            with self.loop._lock:
                with self.service._lock:
                    with self.arena.lock:
                        pass
        """
        assert _codes(LockOrderChecker(), "repro/control/loop.py",
                      code) == []

    def test_lo001_inversion(self):
        code = """
        def bad(self):
            with self.arena.lock:
                with self.service._lock:
                    pass
        """
        assert _codes(LockOrderChecker(), "repro/streams/fleet.py",
                      code) == ["LO001"]

    def test_lo002_unclassified_lock(self):
        code = """
        def f(self):
            with self._mystery_lock:
                pass
        """
        assert _codes(LockOrderChecker(), "repro/streams/fleet.py",
                      code) == ["LO002"]

    def test_lo003_ordered_reentry(self):
        code = """
        def f(a, b):
            with a.arena.lock:
                with b.arena.lock:
                    pass
        """
        assert _codes(LockOrderChecker(), "repro/streams/queue.py",
                      code) == ["LO003"]

    def test_unordered_tier_nesting_is_legal(self):
        code = """
        def f(self):
            with self._scale_lock:
                with self._stop_lock:
                    pass
        """
        assert _codes(LockOrderChecker(), "repro/streams/pipeline.py",
                      code) == []

    def test_locked_suffix_assumes_module_primary_held(self):
        # fleet's primary is the service lock: re-entering it from a
        # *_locked function is a self-deadlock
        code = """
        def _mutate_locked(self):
            with self._lock:
                pass
        """
        assert _codes(LockOrderChecker(), "repro/streams/fleet.py",
                      code) == ["LO003"]

    def test_locked_fn_override_table(self):
        # _rebind_slots_locked runs under the ARENA lock (override),
        # so acquiring the service lock inside it is an inversion
        code = """
        def _rebind_slots_locked(self):
            with self.service._lock:
                pass
        """
        assert _codes(LockOrderChecker(), "repro/streams/fleet.py",
                      code) == ["LO001"]

    def test_classifier_tables(self):
        assert classify_expr("repro/control/loop.py",
                             "self._lock").name == "loop"
        assert classify_expr("repro/x.py", "self._arena.lock").name \
            == "arena"
        assert classify_expr("repro/x.py", "self._random_thing") is None
        assert classify_site("repro/streams/arena.py", "lock").name \
            == "arena"
        assert classify_site("repro/serve/engine.py",
                             "_acct_lock").name == "sync"
        assert classify_site("repro/streams/arena.py", "nope") is None


# ---------------------------------------------------------------------------
# LayerGuard
# ---------------------------------------------------------------------------

class TestLayerGuard:
    def test_lg001_module_level_upward_import(self):
        code = "from repro.control import ControlLoop\n"
        assert _codes(LayerGuard(), "repro/streams/pipeline.py",
                      code) == ["LG001"]

    def test_lg002_obs_importing_repro(self):
        code = "from repro.streams import CounterArena\n"
        assert _codes(LayerGuard(), "repro/obs/exporter.py",
                      code) == ["LG002"]

    def test_lg003_ft_ban_even_lazily(self):
        code = """
        def f():
            from repro.ft import FaultInjector
            return FaultInjector
        """
        assert _codes(LayerGuard(), "repro/serve/engine.py",
                      code) == ["LG003"]

    def test_lg004_lazy_import_needs_annotation(self):
        code = """
        def __init__(self):
            from repro.control import ControlLoop
            self.loop = ControlLoop
        """
        assert _codes(LayerGuard(), "repro/streams/pipeline.py",
                      code) == ["LG004"]

    def test_lg004_unsanctioned_lazy_target(self):
        code = """
        def f():
            # layer-ok: an annotation cannot sanction a non-inversion
            from repro.train import Trainer
            return Trainer
        """
        assert _codes(LayerGuard(), "repro/streams/pipeline.py",
                      code) == ["LG004"]

    def test_annotated_lazy_inversion_is_clean(self):
        code = """
        def __init__(self):
            # layer-ok: wiring inversion, constructor-only
            from repro.control import ControlLoop
            self.loop = ControlLoop
        """
        assert _codes(LayerGuard(), "repro/streams/pipeline.py",
                      code) == []

    def test_downward_and_stdlib_imports_are_clean(self):
        code = """
        import threading
        from repro.core.monitor import MonitorConfig
        from repro.streams.arena import CounterArena
        """
        assert _codes(LayerGuard(), "repro/streams/fleet.py",
                      code) == []

    def test_relative_imports_resolve(self):
        code = "from .arena import CounterArena\n"
        assert _codes(LayerGuard(), "repro/streams/queue.py", code) == []
        code = "from ..control import ControlLoop\n"
        assert _codes(LayerGuard(), "repro/streams/pipeline.py",
                      code) == ["LG001"]


# ---------------------------------------------------------------------------
# BenignRaceChecker
# ---------------------------------------------------------------------------

class TestBenignRaceChecker:
    def test_br001_unannotated_column_write(self):
        code = """
        def bump(self, end, slot):
            end._tc[slot] += 1.0
        """
        assert _codes(BenignRaceChecker(), "repro/streams/queue.py",
                      code) == ["BR001"]

    def test_br002_bare_annotation(self):
        code = """
        def bump(self, end, slot):
            # benign-race:
            end._tc[slot] += 1.0
        """
        assert _codes(BenignRaceChecker(), "repro/streams/queue.py",
                      code) == ["BR002"]

    def test_annotated_contract_is_clean(self):
        code = """
        def bump(self, end, slot):
            # benign-race: copy-and-zero - costs at most one period
            end._tc[slot] += 1.0
        """
        assert _codes(BenignRaceChecker(), "repro/streams/queue.py",
                      code) == []

    def test_annotation_found_in_comment_block_above(self):
        code = """
        def bump(self, end, slot):
            # the write below races the sampler's copy+zero pair;
            # benign-race: copy-and-zero - one period of loss, tolerated
            # by the estimator contract
            end._tc[slot] += 1.0
        """
        assert _codes(BenignRaceChecker(), "repro/streams/queue.py",
                      code) == []

    def test_arena_lock_scope_is_exempt(self):
        code = """
        def zero(self, slot):
            with self.arena.lock:
                self._tc[slot] = 0.0
        """
        assert _codes(BenignRaceChecker(), "repro/streams/arena.py",
                      code) == []

    def test_locked_fn_is_exempt(self):
        code = """
        def _zero_locked(self, slot):
            self._tc[slot] = 0.0
        """
        assert _codes(BenignRaceChecker(), "repro/streams/arena.py",
                      code) == []

    def test_alias_tracking(self):
        code = """
        def bump(self, slot):
            tc_arr = self._tc
            tc_arr[slot] += 1.0
        """
        assert _codes(BenignRaceChecker(), "repro/streams/queue.py",
                      code) == ["BR001"]

    def test_tuple_unpack_alias_tracking(self):
        code = """
        def harvest(self, slot):
            tc_a, blk_a = self._tc, self._blk
            blk_a[slot] = False
        """
        assert _codes(BenignRaceChecker(), "repro/streams/arena.py",
                      code) == ["BR001"]

    def test_non_column_writes_ignored(self):
        code = """
        def f(self, slot):
            self.totals[slot] += 1.0
        """
        assert _codes(BenignRaceChecker(), "repro/streams/queue.py",
                      code) == []


# ---------------------------------------------------------------------------
# RetraceSentinel + StylePass
# ---------------------------------------------------------------------------

class TestRetraceSentinel:
    def test_rs002_python_branch_on_traced_operand(self):
        code = """
        def _step_math(state, lam):
            if lam > 0:
                return state
            return state
        """
        assert _codes(RetraceSentinel(), "repro/control/policy.py",
                      code) == ["RS002"]

    def test_rs002_reaches_call_graph_helpers(self):
        code = """
        def _step_math(state, lam):
            return _clip(state, lam)

        def _clip(state, lam):
            while lam > 0:
                lam = lam - 1
            return state
        """
        assert _codes(RetraceSentinel(), "repro/control/policy.py",
                      code) == ["RS002"]

    def test_rs002_taint_propagates_through_assignment(self):
        code = """
        def _step_math(state, lam):
            pressure = lam * 2.0
            if pressure > 1.0:
                return state
            return state
        """
        assert _codes(RetraceSentinel(), "repro/control/policy.py",
                      code) == ["RS002"]

    def test_presence_and_shape_checks_are_allowed(self):
        code = """
        def _step_math(state, lam):
            if lam is None:
                return state
            if state.shape[0] > 3:
                return state
            if len(state) > 2 and isinstance(lam, float):
                return state
            return state
        """
        assert _codes(RetraceSentinel(), "repro/control/policy.py",
                      code) == []

    def test_untraced_module_not_checked(self):
        code = """
        def _step_math(state, lam):
            if lam > 0:
                return state
            return state
        """
        assert _codes(RetraceSentinel(), "repro/launch/sweep.py",
                      code) == []

    def test_rs001_mutable_default_on_static_param(self):
        code = """
        import jax

        def run(x, opts=[1, 2]):
            return x

        run_j = jax.jit(run, static_argnums=(1,))
        """
        assert "RS001" in _codes(RetraceSentinel(),
                                 "repro/kernels/monitor/ops.py", code)

    def test_rs001_unhashable_literal_at_static_position(self):
        code = """
        import jax

        step = jax.jit(fn, static_argnums=(1,))

        def g(x):
            return step(x, [1, 2])
        """
        assert _codes(RetraceSentinel(), "repro/core/monitor.py",
                      code) == ["RS001"]

    def test_rs003_donated_buffer_escape(self):
        code = """
        import jax

        def drive(self):
            step = jax.jit(f, donate_argnums=(0,))
            out = step(self.state)
            return self.state
        """
        assert _codes(RetraceSentinel(), "repro/core/monitor.py",
                      code) == ["RS003"]

    def test_rs003_same_statement_rebind_is_sanctioned(self):
        code = """
        import jax

        def drive(self):
            step = jax.jit(f, donate_argnums=(0,))
            self.state = step(self.state)
            return self.state
        """
        assert _codes(RetraceSentinel(), "repro/core/monitor.py",
                      code) == []

    def test_rs003_control_decide_donate_kwarg(self):
        code = """
        def tick(self):
            dec = control_decide(cfg, self.state, donate=True)
            return self.state.occ
        """
        assert _codes(RetraceSentinel(), "repro/control/loop.py",
                      code) == ["RS003"]

    def test_rs003_try_fallback_rebind_no_false_positive(self):
        # the real loop.py idiom: donation + rebind inside a try whose
        # except falls back — must NOT leak a donation to the outer
        # block (regression for the compound-statement scan)
        code = """
        def tick(self):
            try:
                self.state, dec = control_decide(
                    cfg, self.state, donate=True)
            except ValueError:
                dec = None
            return self.state
        """
        assert _codes(RetraceSentinel(), "repro/control/loop.py",
                      code) == []


class TestStylePass:
    def test_st101_wall_clock_call(self):
        code = """
        import time

        def f():
            return time.time()
        """
        assert _codes(StylePass(), "repro/streams/queue.py",
                      code) == ["ST101"]

    def test_st101_annotated_is_clean(self):
        code = """
        import time

        def stamp():
            # wall-clock: cross-process timestamp for the audit log
            return time.time()
        """
        assert _codes(StylePass(), "repro/control/log.py", code) == []

    def test_st101_attribute_reference_is_not_a_call(self):
        code = """
        import dataclasses
        import time

        @dataclasses.dataclass
        class Rec:
            t: float = dataclasses.field(default_factory=time.time)
        """
        assert _codes(StylePass(), "repro/control/log.py", code) == []

    def test_st102_broad_except_in_train(self):
        code = """
        def f():
            try:
                g()
            except Exception:
                pass
        """
        assert _codes(StylePass(), "repro/train/trainer.py",
                      code) == ["ST102"]

    def test_st102_bare_except_in_launch(self):
        code = """
        def f():
            try:
                g()
            except:
                pass
        """
        assert _codes(StylePass(), "repro/launch/sweep.py",
                      code) == ["ST102"]

    def test_st102_scoped_to_train_launch_only(self):
        code = """
        def f():
            try:
                g()
            except Exception:
                pass
        """
        assert _codes(StylePass(), "repro/streams/pipeline.py",
                      code) == []

    def test_st102_crash_containment_annotation(self):
        code = """
        def f():
            try:
                g()
            # crash-containment: worker thread must never die silently
            except Exception:
                pass
        """
        assert _codes(StylePass(), "repro/train/trainer.py", code) == []


# ---------------------------------------------------------------------------
# Fingerprints + baseline workflow
# ---------------------------------------------------------------------------

class TestBaseline:
    BAD = """
    import time

    def f():
        return time.time()
    """

    def test_fingerprint_survives_line_shift(self):
        a = list(StylePass().check(_src("repro/x/y.py", self.BAD)))
        shifted = "# a new comment line\n" + textwrap.dedent(self.BAD)
        b = list(StylePass().check(Source("<test>", "repro/x/y.py",
                                          shifted)))
        assert a[0].line != b[0].line
        assert a[0].fingerprint == b[0].fingerprint

    def test_fingerprint_dies_with_the_code(self):
        a = list(StylePass().check(_src("repro/x/y.py", self.BAD)))
        changed = textwrap.dedent(self.BAD).replace(
            "return time.time()", "return 1.0 + time.time()")
        b = list(StylePass().check(Source("<test>", "repro/x/y.py",
                                          changed)))
        assert a[0].fingerprint != b[0].fingerprint

    def test_split_new_baselined_stale(self, tmp_path):
        findings = list(StylePass().check(_src("repro/x/y.py", self.BAD)))
        bl_path = str(tmp_path / "baseline.json")
        Baseline().save(bl_path, findings)
        bl = Baseline.load(bl_path)
        new, old, stale = bl.split(findings)
        assert (len(new), len(old), len(stale)) == (0, 1, 0)
        new, old, stale = bl.split([])          # finding fixed -> stale
        assert (len(new), len(old), len(stale)) == (0, 0, 1)
        new, old, stale = Baseline().split(findings)
        assert (len(new), len(old), len(stale)) == (1, 0, 0)


class TestCli:
    BAD = ("import time\n"
           "def f():\n"
           "    return time.time()\n")
    CLEAN = ("import time\n"
             "def f():\n"
             "    return time.monotonic()\n")

    def test_exit_codes_and_baseline_roundtrip(self, tmp_path, capsys):
        bad = tmp_path / "bad.py"
        bad.write_text(self.BAD)
        bl = str(tmp_path / "baseline.json")
        assert main([str(bad), "--baseline", bl]) == 1
        assert "ST101" in capsys.readouterr().out
        assert main([str(bad), "--baseline", bl,
                     "--write-baseline"]) == 0
        assert main([str(bad), "--baseline", bl]) == 0   # baselined
        assert main([str(bad), "--baseline", bl,
                     "--no-baseline"]) == 1               # raw report
        bad.write_text(self.CLEAN)                        # fixed
        assert main([str(bad), "--baseline", bl]) == 1    # stale entry
        assert "stale" in capsys.readouterr().out

    def test_missing_path_is_usage_error(self, tmp_path):
        assert main([str(tmp_path / "nope.py")]) == 2


def test_src_tree_is_clean_against_shipped_baseline():
    """The tier-1 incarnation of the smoke gate: every checker over the
    real tree, matched against the shipped baseline (which is empty)."""
    findings = run_analysis([SRC])
    bl = Baseline.load(DEFAULT_BASELINE)
    new, _, stale = bl.split(findings)
    assert not new, "unbaselined findings:\n" + "\n".join(
        f.render() for f in new)
    assert not stale, f"stale baseline entries: {stale}"


def test_all_checkers_registry():
    names = {c.name for c in ALL_CHECKERS}
    assert names == {"LockOrderChecker", "LayerGuard",
                     "BenignRaceChecker", "RetraceSentinel", "StylePass"}


# ---------------------------------------------------------------------------
# LockWitness (runtime)
# ---------------------------------------------------------------------------

def _site_module(tmp_path, rel, attrs, kinds=None):
    """Write a module at ``tmp_path/<rel>`` whose ``make_<attr>()``
    functions create a lock at a creation site classify_site maps to a
    hierarchy level, and return its namespace."""
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    kinds = kinds or {}
    lines = ["import threading"]
    for attr in attrs:
        kind = kinds.get(attr, "Lock")
        lines += [f"def make_{attr}():",
                  f"    {attr} = threading.{kind}()",
                  f"    return {attr}"]
    code = "\n".join(lines) + "\n"
    path.write_text(code)
    ns = {}
    exec(compile(code, str(path), "exec"), ns)
    return ns


class TestLockWitness:
    def test_classified_sites_get_wrapped_unclassified_stay_raw(
            self, tmp_path):
        fleet = _site_module(tmp_path, "repro/streams/fleet.py",
                             ["_lock"])
        with LockWitness() as w:
            svc = fleet["make__lock"]()
            raw = threading.Lock()            # this file: unclassified
        assert isinstance(svc, WitnessedLock)
        assert svc.level.name == "service"
        assert not isinstance(raw, WitnessedLock)
        assert w.report() == []

    def test_deactivate_restores_factories(self, tmp_path):
        before = (threading.Lock, threading.RLock)
        w = LockWitness().activate()
        assert (threading.Lock, threading.RLock) != before
        w.deactivate()
        assert (threading.Lock, threading.RLock) == before
        w.deactivate()                        # idempotent
        assert (threading.Lock, threading.RLock) == before

    def test_double_activation_refused(self):
        w = LockWitness().activate()
        try:
            with pytest.raises(RuntimeError, match="already active"):
                w.activate()
        finally:
            w.deactivate()

    def test_inversion_recorded(self, tmp_path):
        fleet = _site_module(tmp_path, "repro/streams/fleet.py",
                             ["_lock"])
        loop = _site_module(tmp_path, "repro/control/loop.py",
                            ["_lock"])
        with LockWitness() as w:
            svc, lp = fleet["make__lock"](), loop["make__lock"]()
            with svc:
                with lp:                       # service held, loop outer
                    pass
        report = w.report()
        assert len(report) == 1 and "inversion" in report[0]
        assert "service" in report[0] and "loop" in report[0]

    def test_declared_order_records_nothing(self, tmp_path):
        loop = _site_module(tmp_path, "repro/control/loop.py", ["_lock"])
        fleet = _site_module(tmp_path, "repro/streams/fleet.py",
                             ["_lock"])
        arena = _site_module(tmp_path, "repro/streams/arena.py",
                             ["lock"], kinds={"lock": "RLock"})
        with LockWitness() as w:
            lp, svc, ar = (loop["make__lock"](), fleet["make__lock"](),
                           arena["make_lock"]())
            with lp:
                with svc:
                    with ar:
                        pass
        assert w.report() == []

    def test_reentrant_rlock_is_not_a_violation(self, tmp_path):
        arena = _site_module(tmp_path, "repro/streams/arena.py",
                             ["lock"], kinds={"lock": "RLock"})
        with LockWitness() as w:
            ar = arena["make_lock"]()
            with ar:
                with ar:                       # RLock re-entry
                    pass
        assert w.report() == []

    def test_same_ordered_rank_nesting_recorded(self, tmp_path):
        fleet = _site_module(tmp_path, "repro/streams/fleet.py",
                             ["_lock"])
        with LockWitness() as w:
            a, b = fleet["make__lock"](), fleet["make__lock"]()
            with a:
                with b:                        # two service-rank locks
                    pass
        report = w.report()
        assert len(report) == 1 and "same-rank" in report[0]

    def test_unordered_tier_abba_cycle_detected(self, tmp_path):
        engine = _site_module(tmp_path, "repro/serve/engine.py",
                              ["_scale_lock", "_acct_lock"])
        with LockWitness() as w:
            a = engine["make__scale_lock"]()
            b = engine["make__acct_lock"]()
            with a:
                with b:                        # edge a -> b (legal tier)
                    pass
            with b:
                with a:                        # edge b -> a: ABBA
                    pass
        assert w.violations == []              # no static-rank violation
        report = w.report()
        assert len(report) == 1 and "cycle" in report[0]

    def test_unordered_tier_consistent_order_is_clean(self, tmp_path):
        engine = _site_module(tmp_path, "repro/serve/engine.py",
                              ["_scale_lock", "_acct_lock"])
        with LockWitness() as w:
            a = engine["make__scale_lock"]()
            b = engine["make__acct_lock"]()
            for _ in range(3):
                with a:
                    with b:
                        pass
        assert w.report() == []

    def test_cross_thread_inversion_caught(self, tmp_path):
        """The witness sees call-graph nesting the AST checker cannot:
        a worker thread acquiring outer-rank under inner-rank."""
        fleet = _site_module(tmp_path, "repro/streams/fleet.py",
                             ["_lock"])
        arena = _site_module(tmp_path, "repro/streams/arena.py",
                             ["lock"], kinds={"lock": "RLock"})
        with LockWitness() as w:
            svc, ar = fleet["make__lock"](), arena["make_lock"]()

            def worker():
                with ar:
                    with svc:                  # arena held, service outer
                        pass

            t = threading.Thread(target=worker)
            t.start()
            t.join()
        report = w.report()
        assert len(report) == 1 and "inversion" in report[0]

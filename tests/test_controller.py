import numpy as np
import pytest

from repro.core.controller import (BufferAutotuner, DistributionClassifier,
                                   ParallelismController, StragglerDetector)
from repro.core.queueing import mm1k_throughput


def test_autotuner_recommendation_achieves_target():
    bt = BufferAutotuner(target_frac=0.99, current=4)
    k = bt.recommend(lam=9e5, mu=1e6)
    assert float(mm1k_throughput(9e5, 1e6, k)) >= 0.99 * 9e5


def test_autotuner_hysteresis():
    bt = BufferAutotuner(current=64, resize_factor=1.5)
    k, resized = bt.maybe_resize(lam=1e5, mu=1e6)   # tiny rho -> small K
    assert resized and k < 64
    k2, resized2 = bt.maybe_resize(lam=1.05e5, mu=1e6)
    assert not resized2                              # within hysteresis


def test_parallelism_controller():
    pc = ParallelismController(headroom=1.2)
    assert pc.replicas(upstream_rate=10e6, stage_rate=1e6) == 12
    assert pc.replicas(upstream_rate=1e5, stage_rate=1e6) == 1
    n, change = pc.should_scale(1, 5e6, 1e6)
    assert change and n == 6


def test_straggler_detector():
    sd = StragglerDetector(threshold=0.8, min_hosts=4)
    for i in range(7):
        sd.report(f"h{i}", 100.0)
    sd.report("h7", 50.0)
    assert sd.stragglers() == ["h7"]
    assert sd.healthy_fraction() == pytest.approx(7 / 8)


def test_distribution_classifier():
    rng = np.random.default_rng(0)
    dc = DistributionClassifier()
    dc.update_batch(rng.exponential(1.0, 800))
    assert dc.classify() == "M"
    dd = DistributionClassifier()
    dd.update_batch(np.full(100, 2.5) + rng.normal(0, 0.01, 100))
    assert dd.classify() == "D"

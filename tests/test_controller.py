import numpy as np
import pytest

from repro.core.controller import (BufferAutotuner, DistributionClassifier,
                                   ParallelismController, StragglerDetector)
from repro.core.queueing import mm1k_throughput


def test_autotuner_recommendation_achieves_target():
    bt = BufferAutotuner(target_frac=0.99, current=4)
    k = bt.recommend(lam=9e5, mu=1e6)
    assert float(mm1k_throughput(9e5, 1e6, k)) >= 0.99 * 9e5


def test_autotuner_hysteresis():
    bt = BufferAutotuner(current=64, resize_factor=1.5)
    k, resized = bt.maybe_resize(lam=1e5, mu=1e6)   # tiny rho -> small K
    assert resized and k < 64
    k2, resized2 = bt.maybe_resize(lam=1.05e5, mu=1e6)
    assert not resized2                              # within hysteresis


def test_parallelism_controller():
    pc = ParallelismController(headroom=1.2)
    assert pc.replicas(upstream_rate=10e6, stage_rate=1e6) == 12
    assert pc.replicas(upstream_rate=1e5, stage_rate=1e6) == 1
    n, change = pc.should_scale(1, 5e6, 1e6)
    assert change and n == 6


def test_straggler_detector():
    sd = StragglerDetector(threshold=0.8, min_hosts=4)
    for i in range(7):
        sd.report(f"h{i}", 100.0)
    sd.report("h7", 50.0)
    assert sd.stragglers() == ["h7"]
    assert sd.healthy_fraction() == pytest.approx(7 / 8)


def test_distribution_classifier():
    rng = np.random.default_rng(0)
    dc = DistributionClassifier()
    dc.update_batch(rng.exponential(1.0, 800))
    assert dc.classify() == "M"
    dd = DistributionClassifier()
    dd.update_batch(np.full(100, 2.5) + rng.normal(0, 0.01, 100))
    assert dd.classify() == "D"


# ---------------------------------------------------------------------------
# Array-in/array-out fleet forms (PR 2): one fused evaluation must agree
# with the scalar controllers elementwise.
# ---------------------------------------------------------------------------

def test_autotuner_fleet_matches_scalar():
    rng = np.random.default_rng(11)
    lam = rng.uniform(1e3, 2e6, 50)
    mu = rng.uniform(1e3, 2e6, 50)      # includes rho > 1 elements
    cv2 = rng.choice([0.0, 0.3, 1.0, 2.0], 50)
    bt = BufferAutotuner(target_frac=0.99, current=4)
    fleet = bt.recommend_fleet(lam, mu, cv2=cv2)
    scalar = [bt.recommend(la, m, c) for la, m, c in zip(lam, mu, cv2)]
    np.testing.assert_array_equal(fleet, scalar)
    # unobservable rates keep the per-queue current capacity
    cur = np.array([7, 9], np.int64)
    out = bt.recommend_fleet([0.0, -1.0], [1e5, 1e5], current=cur)
    np.testing.assert_array_equal(out, cur)


def test_autotuner_maybe_resize_fleet_hysteresis():
    bt = BufferAutotuner(resize_factor=1.5)
    cur = np.array([64, 64], np.int64)
    lam = np.array([1e5, 1e5])
    mu = np.array([1e6, 1e6])
    caps, resized = bt.maybe_resize_fleet(lam, mu, cur)
    assert resized.all() and (caps < 64).all()     # big move: resize
    caps2, resized2 = bt.maybe_resize_fleet(lam * 1.05, mu, caps)
    assert not resized2.any()                      # within hysteresis
    np.testing.assert_array_equal(caps2, caps)


def test_parallelism_fleet_matches_scalar():
    rng = np.random.default_rng(5)
    up = rng.uniform(0, 1e7, 64)
    mu = np.where(rng.random(64) < 0.1, 0.0, rng.uniform(1e4, 1e6, 64))
    pc = ParallelismController(headroom=1.2)
    fleet = pc.replicas_fleet(up, mu)
    scalar = [pc.replicas(u, m) for u, m in zip(up, mu)]
    np.testing.assert_array_equal(fleet, scalar)


def test_straggler_fleet_report_and_mask():
    sd = StragglerDetector(threshold=0.8, min_hosts=4)
    rates = np.array([100.0] * 7 + [50.0])
    sd.report_fleet([f"h{i}" for i in range(8)], rates)
    assert sd.stragglers() == ["h7"]
    mask = sd.straggler_mask(rates)
    np.testing.assert_array_equal(mask, [False] * 7 + [True])
    # unobserved (rate 0) entries are neither stragglers nor counted
    assert not sd.straggler_mask(np.array([0.0, 100.0, 0.0, 90.0])).any()


def test_distribution_classifier_fleet():
    rng = np.random.default_rng(0)
    dc = DistributionClassifier(n_streams=3)
    tile = np.stack([rng.exponential(1.0, 800),
                     np.full(800, 2.5) + rng.normal(0, 0.01, 800),
                     rng.lognormal(0.0, 1.5, 800)])
    dc.update_batch(tile)
    np.testing.assert_array_equal(dc.classify(), ["M", "D", "G"])
    # masked rows fold nothing
    dm = DistributionClassifier(n_streams=2)
    dm.update_batch(np.ones((2, 16)),
                    where=np.stack([np.ones(16, bool), np.zeros(16, bool)]))
    np.testing.assert_array_equal(dm.counts, [16.0, 0.0])


def test_distribution_classifier_batch_matches_per_sample():
    """The vectorized Pebay fold reproduces the per-sample update."""
    rng = np.random.default_rng(2)
    xs = rng.gamma(2.0, 1.5, 300)
    a = DistributionClassifier()
    for x in xs:
        a.update(float(x))
    b = DistributionClassifier()
    b.update_batch(xs)
    assert a.classify() == b.classify()
    assert b.cv2 == pytest.approx(a.cv2, rel=1e-3)

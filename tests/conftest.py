"""Shared test setup.

The container image does not ship ``hypothesis`` (and installing packages
is off-limits), so when the real library is absent we install a tiny
deterministic stand-in that supports exactly the API surface these tests
use — ``given``/``settings`` and the ``floats``/``integers``/``lists``
strategies — drawing a fixed number of seeded random examples per test.
With the real library installed, this file does nothing.

``_no_thread_leaks`` is the tier-1 hygiene gate for a codebase whose
subjects are all threads (serve workers, monitor/control/supervisor
loops): a test that exits leaving a non-daemon thread alive would hang
the interpreter at shutdown, so it fails loudly here instead.

``_lock_order_witness`` arms ``repro.analysis``'s runtime lock witness
for the concurrency suites: every ``threading.Lock``/``RLock`` created
at a site named in the canonical ``LOCK_ORDER`` table is wrapped, and a
test fails if any thread's real acquisition order inverts the hierarchy
or forms a cross-thread cycle.  Suites outside ``_WITNESS_SUITES`` (and
all locks created from non-contract sites) pay nothing.
"""

from __future__ import annotations

import functools
import sys
import threading
import time
import types

import pytest


@pytest.fixture(autouse=True)
def _no_thread_leaks():
    """Fail any test that leaves a new non-daemon thread running.

    Daemon threads (every repro worker/monitor/loop) are exempt — the
    gate catches the plain ``threading.Thread()`` default a test helper
    forgets to join, which would wedge pytest's exit."""
    before = set(threading.enumerate())
    yield
    deadline = time.monotonic() + 2.0   # grace for in-flight joins
    while True:
        leaked = [t for t in threading.enumerate()
                  if not t.daemon and t.is_alive() and t not in before]
        if not leaked:
            return
        if time.monotonic() >= deadline:
            pytest.fail("test leaked non-daemon threads: "
                        f"{sorted(t.name for t in leaked)}")
        time.sleep(0.05)


# the tier-1 concurrency suites the runtime lock witness covers (the
# ISSUE-10 acceptance set plus the exporter-concurrency tests)
_WITNESS_SUITES = {"test_control", "test_selfheal", "test_qos",
                   "test_arena", "test_obs"}


@pytest.fixture(autouse=True)
def _lock_order_witness(request):
    mod = getattr(getattr(request, "node", None), "module", None)
    name = getattr(mod, "__name__", "").rpartition(".")[2]
    if name not in _WITNESS_SUITES:
        yield
        return
    from repro.analysis.witness import LockWitness
    witness = LockWitness().activate()
    try:
        yield
    finally:
        witness.deactivate()
        problems = witness.report()
        if problems:
            pytest.fail(
                "LockWitness recorded lock-hierarchy hazards (see "
                "repro.analysis.lock_order.LOCK_ORDER):\n  "
                + "\n  ".join(problems), pytrace=False)


def _install_hypothesis_stub() -> None:
    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def floats(min_value, max_value, allow_nan=None, **_kw):
        span = (float(min_value), float(max_value))

        def draw(rng, _s=span):
            return float(rng.uniform(_s[0], _s[1]))
        return _Strategy(draw)

    def integers(min_value, max_value):
        def draw(rng):
            return int(rng.integers(int(min_value), int(max_value) + 1))
        return _Strategy(draw)

    def lists(elements, min_size=0, max_size=None, **_kw):
        def draw(rng):
            hi = max_size if max_size is not None else min_size + 10
            n = int(rng.integers(min_size, hi + 1))
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw)

    def settings(**kwargs):
        def deco(fn):
            fn._stub_settings = dict(kwargs)
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                cfg = getattr(wrapper, "_stub_settings", {})
                n = min(int(cfg.get("max_examples", 20)), 25)
                rng = np.random.default_rng(0)
                for _ in range(n):
                    fn(*args, *(s.draw(rng) for s in strats), **kwargs)
            # hide the drawn parameters from pytest's fixture resolution
            # (like hypothesis, strategies fill the rightmost parameters)
            import inspect
            params = list(inspect.signature(fn).parameters.values())
            wrapper.__signature__ = inspect.Signature(
                params[:len(params) - len(strats)])
            del wrapper.__wrapped__
            return wrapper
        return deco

    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.floats, st.integers, st.lists = floats, integers, lists
    mod.given, mod.settings, mod.strategies = given, settings, st
    mod.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401
except ImportError:                                    # pragma: no cover
    _install_hypothesis_stub()

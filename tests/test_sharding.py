import subprocess
import sys
import textwrap

import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.dist.sharding import (act_rules, act_rules_opt, param_rules,
                                 param_rules_opt, resolve_profile,
                                 spec_for)


class FakeMesh:
    shape = {"pod": 2, "data": 16, "model": 16}


MESH = FakeMesh()


def test_divisibility_fallback_heads_to_headdim():
    rules = param_rules(multi_pod=False)
    # phi4: 24 heads %16 != 0 -> head_dim (128) takes 'model'
    spec = spec_for((3072, 24, 128),
                    ("d_model", "heads", "head_dim"), rules, MESH)
    assert spec == P("data", None, "model")
    # grok: 48 heads divisible -> heads take 'model' (trailing None
    # dims are trimmed from the spec)
    spec = spec_for((6144, 48, 128),
                    ("d_model", "heads", "head_dim"), rules, MESH)
    assert spec == P("data", "model")


def test_priority_prefers_kv_heads_over_qseq():
    rules = act_rules("train", multi_pod=False)
    # zamba: 32 kv heads divisible -> kv_heads win the 'model' axis
    spec = spec_for((32, 32, 1, 4096, 4096),
                    ("batch", "kv_heads", "q_per_kv", "q_seq", "kv_seq"),
                    rules, MESH)
    assert spec == P("data", "model")
    # internlm: kv=8 not divisible -> q_seq takes it
    spec = spec_for((32, 8, 2, 4096, 4096),
                    ("batch", "kv_heads", "q_per_kv", "q_seq", "kv_seq"),
                    rules, MESH)
    assert spec == P("data", None, None, "model")


def test_one_mesh_axis_per_tensor():
    rules = param_rules(multi_pod=False)
    for a in ARCH_IDS:
        cfg = get_config(a)
        spec = spec_for((cfg.padded_vocab, cfg.d_model),
                        ("vocab", "d_model"), rules, MESH)
        used = [x for part in spec if part
                for x in (part if isinstance(part, tuple) else (part,))]
        assert len(used) == len(set(used))


def test_batch_one_cannot_shard_falls_through():
    rules = act_rules("decode", multi_pod=False)
    # long_500k: batch=1 -> cache_seq takes (data, model)
    spec = spec_for((64, 1, 524_288, 8, 128),
                    ("layers", "batch", "cache_seq", "kv_heads",
                     "head_dim"), rules, MESH)
    assert spec == P(None, None, ("data", "model"))


def test_resolve_profile_moe_mesh_for_moe_archs():
    # perf it.6: ALL MoE archs use the shard_map EP mesh (auto-SPMD EP
    # replicates the dispatch scatter)
    for arch in ("grok-1-314b", "phi3.5-moe-42b-a6.6b"):
        _, _, kind = resolve_profile("opt", get_config(arch), "train",
                                     False)
        assert kind == "moe"
    _, _, kind = resolve_profile("opt", get_config("internlm2-1.8b"),
                                 "train", False)
    assert kind == "canonical"


def test_multipod_batch_uses_pod_axis():
    rules = act_rules_opt("train", multi_pod=True)
    spec = spec_for((256, 4096, 3072), ("batch", "seq", "d_model"),
                    rules, MESH)
    assert spec == P(("pod", "data"), "model")


_SMALL_MESH_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro.configs import get_smoke_config, ShapeConfig
    from repro.dist.api import ShardingContext, use_sharding
    from repro.dist.sharding import act_rules, param_rules, \\
        param_specs_tree, spec_for
    from repro.launch.mesh import make_local_mesh
    from repro.models import build_model

    mesh = make_local_mesh(2, 4)
    cfg = get_smoke_config("internlm2-1.8b")
    model = build_model(cfg)
    ctx = ShardingContext(mesh, act_rules("train", False),
                          param_rules(False))
    ap = model.abstract_params()
    specs = param_specs_tree(model.param_axes(), ap, mesh,
                             ctx.param_rules)
    p_sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)
    batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
             "targets": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    with use_sharding(ctx), mesh:
        lowered = jax.jit(lambda p, b: model.loss(p, b)[0],
                          in_shardings=(p_sh, None)).lower(ap, batch)
        compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):   # older jax: one dict per computation
        ca = ca[0]
    assert ca["flops"] > 0
    print("SMALL_MESH_OK")
""")


def test_small_mesh_lower_compile():
    """End-to-end sharded lower+compile on an 8-device local mesh (own
    process: jax device count locks at first init)."""
    r = subprocess.run([sys.executable, "-c", _SMALL_MESH_PROG],
                       capture_output=True, text=True, timeout=600)
    assert "SMALL_MESH_OK" in r.stdout, r.stderr[-2000:]

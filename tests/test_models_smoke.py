"""Per-assigned-architecture smoke tests: reduced config of the same
family, one train + prefill + decode step on CPU, asserting output shapes
and finiteness (assignment requirement f)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, applicable, get_config, \
    get_smoke_config
from repro.models import build_model


def _batch_for(cfg, B, S, key):
    if cfg.input_kind == "embeds":
        return {"embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.bfloat16),
                "targets": jnp.zeros((B, S), jnp.int32)}
    if cfg.input_kind == "frames+tokens":
        return {"frames": jax.random.normal(
                    key, (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16),
                "tokens": jnp.zeros((B, S), jnp.int32),
                "targets": jnp.zeros((B, S), jnp.int32)}
    return {"tokens": jnp.zeros((B, S), jnp.int32),
            "targets": jnp.zeros((B, S), jnp.int32)}


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id):
    cfg = get_smoke_config(arch_id)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, 2, 16, jax.random.PRNGKey(1))
    loss, metrics = jax.jit(lambda p, b: model.loss(p, b))(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    assert np.isfinite(float(metrics["ce"]))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_prefill_decode(arch_id):
    cfg = get_smoke_config(arch_id)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = _batch_for(cfg, B, S, jax.random.PRNGKey(1))
    batch.pop("targets")
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    full = model.init_cache(B, S + 4)
    nt, new_cache = jax.jit(model.decode_step)(
        params, full, jnp.zeros((B,), jnp.int32),
        jnp.full((B,), S, jnp.int32))
    assert nt.shape == (B,)
    assert nt.dtype == jnp.int32


@pytest.mark.parametrize("arch_id", ["internlm2-1.8b", "gemma2-2b",
                                     "mamba2-2.7b", "zamba2-7b",
                                     "whisper-large-v3"])
def test_decode_matches_prefill(arch_id):
    """Incremental decoding must agree with the full forward pass."""
    cfg = get_smoke_config(arch_id)
    model = build_model(cfg, compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    pf = {"tokens": toks}
    if cfg.input_kind == "frames+tokens":
        pf["frames"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.encoder_seq, cfg.d_model),
            jnp.float32)
    logits_full, _ = model.prefill(params, pf)
    pf2 = dict(pf)
    pf2["tokens"] = toks[:, :S - 1]
    _, cache = model.prefill(params, pf2)
    cache = {k: (jnp.pad(v, [(0, 0), (0, 0), (0, 1), (0, 0), (0, 0)])
                 if k in ("k", "v") else v) for k, v in cache.items()}
    nt, _ = model.decode_step(params, cache, toks[:, S - 1],
                              jnp.full((B,), S - 1, jnp.int32))
    assert bool(jnp.all(nt == jnp.argmax(logits_full[:, -1], -1)))


def test_all_40_cells_are_defined():
    cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    assert len(cells) == 40
    n_skip = sum(not applicable(get_config(a), SHAPES[s])[0]
                 for a, s in cells)
    # long_500k runs only for ssm+hybrid => 8 full-attention archs skip it
    assert n_skip == 8


def test_param_counts_sane():
    expect = {"phi4-mini-3.8b": 3.8e9, "gemma2-2b": 2.6e9,
              "internlm2-1.8b": 1.9e9, "phi3-medium-14b": 14e9,
              "grok-1-314b": 314e9, "phi3.5-moe-42b-a6.6b": 42e9,
              "mamba2-2.7b": 2.7e9, "zamba2-7b": 7e9,
              "qwen2-vl-72b": 72e9, "whisper-large-v3": 1.6e9}
    for a, n in expect.items():
        got = get_config(a).n_params()
        assert got == pytest.approx(n, rel=0.35), (a, got)


def test_moe_active_params_less_than_total():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    assert cfg.n_active_params() < cfg.n_params() / 3
    assert cfg.n_active_params() == pytest.approx(6.6e9, rel=0.35)

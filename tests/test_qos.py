"""QoS classes + bulkhead isolation (PR 7).

Covers: the class registry, per-class lane routing over contiguous
arena slot spans, the one-way bounded borrow rule, Engine.stop()
releasing deferred admission waiters (satellite 1), per-class
rejection/deferral accounting distinguishable in admission_state() and
the ControlLog (satellite 2), deadline drops at pop, and the
class-aware admission legs of the fused decision (occ_hi/occ_lo bands,
pressure semantics, numpy/jit parity, zero retraces across class
churn).
"""

import threading
import time

import numpy as np
import pytest

from repro.control import (AdmissionPolicy, ControlConfig, ControlLog,
                           control_decide, control_decide_trace_count,
                           control_init)
from repro.serve import (BLOCKING, NONBLOCKING, Engine, QoSClass, Request,
                         ServeConfig, qos_class, qos_classes,
                         register_qos_class)
from repro.streams import CounterArena


class _WorkEngine(Engine):
    """Model-free engine: _serve_batch burns ``work_s`` and completes
    every request — the serving path without a model on the device."""

    def __init__(self, scfg, work_s=0.0, **kw):
        super().__init__(None, None, scfg, **kw)
        self.work_s = work_s

    def _serve_batch(self, batch):
        if self.work_s:
            time.sleep(self.work_s)
        for r in batch:
            r.out = np.zeros(1, np.int32)
            r.done.set()
            self.served += 1


def _req(i, qos=BLOCKING, deadline_s=None):
    return Request(rid=i, tokens=np.arange(4), max_new=1, qos=qos,
                   deadline_s=deadline_s)


# -- registry ---------------------------------------------------------------

def test_registry_builtins_and_custom():
    assert BLOCKING in qos_classes() and NONBLOCKING in qos_classes()
    assert not qos_class(BLOCKING).patient
    nb = qos_class(NONBLOCKING)
    assert nb.patient and nb.mode == "shed"
    c = QoSClass("bulk_test", patient=True, mode="defer",
                 occupancy_hi=0.5, occupancy_lo=0.2, deadline_s=1.0)
    register_qos_class(c)
    assert qos_class("bulk_test") is c
    with pytest.raises(ValueError):
        register_qos_class(QoSClass("bulk_test"))
    register_qos_class(QoSClass("bulk_test", patient=True), replace=True)
    assert qos_class("bulk_test").mode is None


def test_registry_validation():
    with pytest.raises(ValueError):
        QoSClass("x", mode="explode")
    with pytest.raises(ValueError):
        QoSClass("x", occupancy_hi=1.5)
    with pytest.raises(ValueError):
        QoSClass("x", occupancy_hi=0.3, occupancy_lo=0.6)
    with pytest.raises(KeyError):
        qos_class("never_registered")


# -- lanes + slots ----------------------------------------------------------

def test_lane_routing_and_contiguous_slots():
    eng = _WorkEngine(ServeConfig(batch_size=2, queue_capacity=8),
                      arena=CounterArena(4))
    try:
        slots = eng.lane_slots()
        flat = [s for pair in slots.values() for s in pair]
        # one ascending run across the whole engine block: per-class
        # (head, tail) pairs are adjacent and the classes are stacked
        assert flat == list(range(min(flat), min(flat) + len(flat)))
        eng.start()
        reqs = [_req(0), _req(1, qos=NONBLOCKING)]
        for r in reqs:
            assert eng.submit(r)
        for r in reqs:
            assert r.done.wait(timeout=10)
        st = eng.admission_state()["classes"]
        assert st[BLOCKING]["submitted"] == 1
        assert st[NONBLOCKING]["submitted"] == 1
        assert st[BLOCKING]["served"] + st[NONBLOCKING]["served"] == 2
    finally:
        eng.stop()


def test_unknown_class_raises():
    eng = _WorkEngine(ServeConfig(queue_capacity=4),
                      arena=CounterArena(4))
    try:
        with pytest.raises(KeyError):
            eng.submit(_req(0, qos="no_such_lane"))
    finally:
        eng.stop()


# -- borrowing: one-way, bounded -------------------------------------------

def test_patient_worker_borrows_into_blocking_lane():
    eng = _WorkEngine(ServeConfig(batch_size=4, queue_capacity=16,
                                  bulkheads=(0, 1)),
                      arena=CounterArena(4))
    eng.start()
    try:
        reqs = [_req(i) for i in range(4)]          # blocking lane only
        for r in reqs:
            assert eng.submit(r)
        for r in reqs:
            assert r.done.wait(timeout=10)
        (w,) = eng.workers()
        assert w.qos == NONBLOCKING and w.borrowed >= 1
    finally:
        eng.stop()


def test_blocking_worker_never_borrows():
    eng = _WorkEngine(ServeConfig(batch_size=4, queue_capacity=16,
                                  bulkheads=(1, 0)),
                      arena=CounterArena(4))
    eng.start()
    try:
        r_nb = _req(0, qos=NONBLOCKING)
        assert eng.submit(r_nb)
        r_b = _req(1)
        assert eng.submit(r_b)
        assert r_b.done.wait(timeout=10)            # home lane flows
        # reserved capacity: the patient request is never drained
        assert not r_nb.done.wait(timeout=0.3)
        (w,) = eng.workers()
        assert w.qos == BLOCKING and w.borrowed == 0
    finally:
        eng.stop()


# -- satellite 1: stop() releases deferred waiters --------------------------

def test_stop_releases_deferred_admission_waiters():
    eng = _WorkEngine(ServeConfig(queue_capacity=4),
                      arena=CounterArena(4),
                      admission=AdmissionPolicy(mode="defer"))
    eng.start()
    gate = eng.gates[BLOCKING]
    gate.set_shed(True)                 # shut: defer-mode submits park
    results = []

    def blocked_submit(i):
        results.append(eng.submit(_req(i), timeout=60.0))

    threads = [threading.Thread(target=blocked_submit, args=(i,))
               for i in range(4)]
    for t in threads:
        t.start()
    deadline = time.monotonic() + 5
    while gate.defer_count < 4 and time.monotonic() < deadline:
        time.sleep(0.005)
    assert gate.defer_count == 4        # all four are parked
    t0 = time.monotonic()
    eng.stop()                          # must release them NOW
    for t in threads:
        t.join(timeout=10)
    assert time.monotonic() - t0 < 5    # not the 60 s submit timeout
    assert results == [False] * 4
    assert gate.stop_released == 4
    assert eng.admission_state()["classes"][BLOCKING]["stop_released"] == 4


def test_closed_gate_rejects_future_submits():
    eng = _WorkEngine(ServeConfig(queue_capacity=4),
                      arena=CounterArena(4))
    eng.stop()
    assert not eng.submit(_req(0))
    assert eng.gates[BLOCKING].shed_count == 1


# -- satellite 2: per-class accounting + audit ------------------------------

def test_per_class_rejection_paths_distinguishable():
    eng = _WorkEngine(ServeConfig(batch_size=2, queue_capacity=2,
                                  bulkheads=(0, 0)),   # nothing drains
                      arena=CounterArena(4))
    eng.start()
    try:
        # shed path: shut the nonblocking gate (builtin mode 'shed')
        eng.gates[NONBLOCKING].set_shed(True)
        assert not eng.submit(_req(0, qos=NONBLOCKING))
        # queue-timeout path: blocking gate open, lane full
        assert eng.submit(_req(1))
        assert eng.submit(_req(2))
        assert not eng.submit(_req(3), timeout=0.05)
        st = eng.admission_state()["classes"]
        assert st[NONBLOCKING]["shed"] == 1
        assert st[NONBLOCKING]["queue_timeouts"] == 0
        assert st[BLOCKING]["shed"] == 0
        assert st[BLOCKING]["queue_timeouts"] == 1
        assert st[BLOCKING]["submitted"] == 3
        assert st[BLOCKING]["admitted"] == 2
    finally:
        eng.stop()


def test_gate_flips_land_qos_records_in_control_log():
    eng = _WorkEngine(ServeConfig(queue_capacity=4),
                      arena=CounterArena(4))
    try:
        log = ControlLog()
        eng._actuator.bind_log(log)
        eng.gates[NONBLOCKING].set_shed(True)
        assert not eng.submit(_req(0, qos=NONBLOCKING))
        i = eng.class_names.index(NONBLOCKING)
        eng._actuator.admit(i, True)
        eng._actuator.admit(i, False)
        recs = log.by_policy("qos")
        assert [r.action for r in recs] == ["shed", "admit"]
        assert all(r.qos == NONBLOCKING for r in recs)
        # the class's cumulative rejections ride the record value: a
        # shed is distinguishable from a timeout in the audit stream
        assert recs[0].value == 1
    finally:
        eng.stop()


# -- deadlines --------------------------------------------------------------

def test_expired_request_dropped_at_pop():
    eng = _WorkEngine(ServeConfig(batch_size=2, queue_capacity=8),
                      arena=CounterArena(4))
    r = _req(0, deadline_s=0.05)
    assert eng.submit(r)                # queued; engine not started yet
    time.sleep(0.12)
    eng.start()
    try:
        assert r.done.wait(timeout=10)
        assert r.out is None            # dropped, not served
        st = eng.admission_state()["classes"][BLOCKING]
        assert st["deadline_dropped"] == 1 and st["served"] == 0
    finally:
        eng.stop()


def test_class_default_deadline_applied():
    register_qos_class(QoSClass("deadline_test", deadline_s=0.75),
                       replace=True)
    eng = _WorkEngine(ServeConfig(queue_capacity=4,
                                  qos_classes=(BLOCKING, "deadline_test")),
                      arena=CounterArena(4))
    try:
        r = _req(0, qos="deadline_test")
        eng.start()
        assert eng.submit(r)
        assert r.done.wait(timeout=10)
        assert r.deadline_s == pytest.approx(0.75)
    finally:
        eng.stop()


# -- class-aware admission legs in the fused decision -----------------------

def test_pressure_arms_patient_shed_and_gates_disarm():
    cfg = ControlConfig(confirm_ticks=1, cooldown_ticks=0, min_ready=1)
    q = 2
    bands_hi = np.array([np.nan, 0.6], np.float32)
    bands_lo = np.array([np.nan, 0.3], np.float32)
    state = control_init(cfg, q)
    # blocking lane hot: pressure 0.9 >= occ_hi 0.6 arms the patient
    # lane's gate with NO collapse/straggler evidence of its own
    for _ in range(2):
        state, dec = control_decide(
            cfg, state, lam=np.full(q, 100.0), mu=np.full(q, 100.0),
            ready=np.ones(q, bool), replicas=np.ones(q),
            caps=np.full(q, 64), occupancy=np.array([0.9, 0.1]),
            occ_hi=bands_hi, occ_lo=bands_lo,
            pressure=np.array([0.0, 0.9]), impl="numpy")
    assert dec.shed.tolist() == [False, True]
    # pressure still above occ_lo: disarm is held even though the
    # patient lane itself is empty and healthy
    state, dec = control_decide(
        cfg, state, lam=np.full(q, 100.0), mu=np.full(q, 100.0),
        ready=np.ones(q, bool), replicas=np.ones(q),
        caps=np.full(q, 64), occupancy=np.array([0.9, 0.0]),
        occ_hi=bands_hi, occ_lo=bands_lo,
        pressure=np.array([0.0, 0.5]), impl="numpy")
    assert dec.shed.tolist() == [False, True]
    # pressure cleared: the gate reopens
    state, dec = control_decide(
        cfg, state, lam=np.full(q, 100.0), mu=np.full(q, 100.0),
        ready=np.ones(q, bool), replicas=np.ones(q),
        caps=np.full(q, 64), occupancy=np.array([0.2, 0.0]),
        occ_hi=bands_hi, occ_lo=bands_lo,
        pressure=np.array([0.0, 0.1]), impl="numpy")
    assert dec.shed.tolist() == [False, False]


def test_nan_bands_inherit_config_scalars():
    cfg = ControlConfig(confirm_ticks=1, cooldown_ticks=0, min_ready=1,
                        occupancy_hi=0.9, occupancy_lo=0.5)
    q = 1
    state = control_init(cfg, q)
    kw = dict(ready=np.ones(q, bool), replicas=np.ones(q),
              caps=np.full(q, 64),
              occ_hi=np.array([np.nan], np.float32),
              occ_lo=np.array([np.nan], np.float32), impl="numpy")
    # establish the service-rate peak, then collapse with occ above the
    # CONFIG hi: the NaN band must arm exactly like the class-less path
    state, dec = control_decide(
        cfg, state, lam=np.full(q, 100.0), mu=np.full(q, 100.0),
        occupancy=np.array([0.2]), **kw)
    for _ in range(2):
        state, dec = control_decide(
            cfg, state, lam=np.full(q, 100.0), mu=np.full(q, 10.0),
            occupancy=np.array([0.95]), **kw)
    assert dec.shed.tolist() == [True]


def test_qos_legs_numpy_jit_parity():
    cfg = ControlConfig(confirm_ticks=1, cooldown_ticks=0, min_ready=1,
                        block_q=8)
    q = 3
    kw = dict(lam=np.array([100.0, 80.0, 60.0]),
              mu=np.array([100.0, 90.0, 70.0]),
              ready=np.ones(q, bool), replicas=np.ones(q),
              caps=np.full(q, 64),
              occupancy=np.array([0.9, 0.2, 0.1]),
              occ_hi=np.array([np.nan, 0.6, 0.5], np.float32),
              occ_lo=np.array([np.nan, 0.3, 0.2], np.float32),
              pressure=np.array([0.0, 0.9, 0.4]))
    st_np = control_init(cfg, q)
    st_j = control_init(cfg, q)
    for _ in range(3):
        st_np, d_np = control_decide(cfg, st_np, impl="numpy", **kw)
        st_j, d_j = control_decide(cfg, st_j, impl="jit", donate=False,
                                   **kw)
    for f in ("target_replicas", "scale_mask", "target_caps",
              "resize_mask", "shed", "straggler"):
        np.testing.assert_array_equal(np.asarray(getattr(d_np, f)),
                                      np.asarray(getattr(d_j, f)), f)


def test_qos_operands_do_not_retrace():
    cfg = ControlConfig(confirm_ticks=1, block_q=16,
                        cooldown_ticks=13)          # fresh cache key

    def run(q, hi, lo, prs):
        control_decide(cfg, control_init(cfg, q),
                       lam=np.full(q, 100.0), mu=np.full(q, 50.0),
                       ready=np.ones(q, bool), replicas=np.ones(q),
                       caps=np.full(q, 64), occ_hi=hi, occ_lo=lo,
                       pressure=prs, impl="jit", donate=True)

    base = control_decide_trace_count()
    run(2, None, None, None)
    warm = control_decide_trace_count()
    assert warm > base
    # class churn: lane counts and band/pressure values vary freely
    for q in (2, 3, 5, 16):
        run(q, np.full(q, 0.6, np.float32), np.full(q, 0.3, np.float32),
            np.linspace(0, 1, q))
        run(q, np.full(q, np.nan, np.float32), None, None)
    assert control_decide_trace_count() == warm


# -- engine + control loop end-to-end ---------------------------------------

def test_engine_actuator_senses_bands_and_pressure():
    eng = _WorkEngine(ServeConfig(batch_size=2, queue_capacity=8,
                                  bulkheads=(0, 0)),
                      arena=CounterArena(4))
    try:
        act = eng._actuator
        hi, lo = act.admission_bands()
        assert np.isnan(hi[0]) and hi[1] == pytest.approx(0.6)
        assert np.isnan(lo[0]) and lo[1] == pytest.approx(0.3)
        for i in range(4):                       # blocking lane half full
            eng.lanes[BLOCKING].push(_req(i), timeout=1)
        prs = act.pressure()
        assert prs[0] == 0.0                     # non-patient feels none
        assert prs[1] == pytest.approx(0.5)      # patient feels blocking
    finally:
        eng.stop()


def test_control_loop_sheds_patient_class_under_blocking_pressure():
    """End-to-end: blocking lane runs hot -> the loop's fused decision
    (sensing admission_bands + pressure) shuts the patient gate; the
    blocking gate stays open."""
    eng = _WorkEngine(ServeConfig(batch_size=2, queue_capacity=8,
                                  bulkheads=(0, 0)),
                      arena=CounterArena(4), control=True)
    try:
        for i in range(8):                       # blocking lane FULL
            eng.lanes[BLOCKING].push(_req(i), timeout=1)
        for q in eng.lanes.values():             # make estimates ready
            q.head.tc, q.tail.tc = 100.0, 100.0
        for _ in range(64):
            eng.fleet.sample()
        eng.fleet.flush()
        for _ in range(eng.control.cfg.confirm_ticks + 3):
            eng.control.tick()
        assert eng.gates[NONBLOCKING].shedding
        assert not eng.gates[BLOCKING].shedding
        assert not eng.submit(_req(99, qos=NONBLOCKING))
        recs = eng.control.log.by_policy("qos")
        assert any(r.action == "shed" and r.qos == NONBLOCKING
                   for r in recs)
    finally:
        eng.stop()

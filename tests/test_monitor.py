import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.monitor import (HostMonitor, MonitorConfig,
                                SamplingPeriodController, monitor_init,
                                monitor_update, run_monitor)
from repro.core.simulate import TandemConfig, sample_periods, \
    simulate_tandem


def _drive_host(tc, blocked, cfg=None, period=1e-3):
    hm = HostMonitor(cfg or MonitorConfig(), period_s=period)
    for t, b in zip(tc, blocked):
        hm.update(float(t), bool(b))
    return hm


def test_noise_free_deterministic_exact():
    cfg = TandemConfig(mu_a=4.0e5, mu_b=2.0e5, dist_a="deterministic",
                       dist_b="deterministic", capacity=64,
                       n_items=120_000)
    res = simulate_tandem(cfg)
    tc, blocked, _ = sample_periods(res, 1e-3, timer_jitter_rel=0,
                                    outlier_prob=0, clear_race_prob=0)
    hm = _drive_host(tc, blocked)
    assert hm.epoch >= 1
    assert hm.rate_items_per_s() == pytest.approx(cfg.mu_b, rel=0.01)


def test_noisy_exponential_within_paper_band():
    """Paper Fig. 13: 'the majority of the results are within 20%'."""
    cfg = TandemConfig(mu_a=4.0e5, mu_b=2.0e5, capacity=64,
                       n_items=200_000, seed=7)
    res = simulate_tandem(cfg)
    tc, blocked, _ = sample_periods(res, 1e-3, seed=8)
    hm = _drive_host(tc, blocked)
    assert hm.epoch >= 1
    err = abs(hm.rate_items_per_s() - cfg.mu_b) / cfg.mu_b
    assert err < 0.20


def test_dual_phase_detected():
    """Paper Figs. 10/14: converged estimates track a mid-run rate shift."""
    cfg = TandemConfig(mu_a=8.0e5, mu_b=2.66e5, mu_b2=1.0e5,
                       capacity=64, n_items=300_000, seed=9)
    res = simulate_tandem(cfg)
    tc, blocked, _ = sample_periods(res, 1e-3, seed=10)
    hm = HostMonitor(MonitorConfig(), period_s=1e-3)
    ests = []
    for t, b in zip(tc, blocked):
        if hm.update(float(t), bool(b)):
            ests.append(hm.last_qbar / 1e-3)
    assert len(ests) >= 4
    first, last = ests[0], ests[-1]
    assert first == pytest.approx(cfg.mu_b, rel=0.25)
    assert last == pytest.approx(cfg.mu_b2, rel=0.25)


def test_jax_and_host_agree():
    rng = np.random.default_rng(11)
    tc = rng.poisson(200, 600).astype(np.float64)
    blocked = rng.random(600) < 0.05
    cfg = MonitorConfig()
    hm = _drive_host(tc, blocked, cfg)
    outs = run_monitor(cfg, tc, blocked)
    assert int(outs.epoch[-1]) == hm.epoch
    if hm.epoch:
        assert float(outs.estimate[-1]) == pytest.approx(hm.last_qbar,
                                                         rel=1e-3)


def test_blocked_samples_are_discarded():
    cfg = MonitorConfig()
    state = monitor_init(cfg)
    state1, _ = monitor_update(cfg, state, 100.0, True)
    assert int(state1.s_fill) == 0
    assert int(state1.n_blocked) == 1
    state2, _ = monitor_update(cfg, state, 100.0, False)
    assert int(state2.s_fill) == 1


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(1.0, 1e4, allow_nan=False), min_size=80,
                max_size=200))
def test_property_estimate_within_observed_range(tcs):
    """Invariant: q-bar stays within [min, max] of the observed samples
    scaled by the quantile overshoot bound (mu + z*sigma <= max + z*range).
    """
    tc = np.asarray(tcs)
    hm = _drive_host(tc, np.zeros(len(tc), bool))
    lo, hi = tc.min(), tc.max()
    z = hm.cfg.quantile_z
    if hm.qbar:
        assert lo - z * (hi - lo) <= hm.qbar <= hi + z * (hi - lo)


@settings(max_examples=20, deadline=None)
@given(st.floats(10.0, 1e4), st.integers(0, 2 ** 31 - 1))
def test_property_constant_stream_converges_to_value(val, seed):
    tc = np.full(400, val)
    hm = _drive_host(tc, np.zeros(400, bool))
    assert hm.epoch >= 1
    assert hm.last_qbar == pytest.approx(val, rel=1e-3)


def test_sampling_period_controller_widens_then_fails():
    # stable + unblocked -> widen
    c = SamplingPeriodController(base_latency_s=1e-6, max_period_s=1e-3,
                                 k_no_block=4, j_stable=4)
    t0 = c.period_s
    for _ in range(8):
        c.observe(c.period_s, blocked=False)
    assert c.period_s > t0
    # hopelessly unstable at minimum -> declared failure (paper IV-A)
    c2 = SamplingPeriodController(base_latency_s=1e-6, j_stable=3)
    for _ in range(10):
        c2.observe(c2.period_s * 10, blocked=True)
    assert c2.failed

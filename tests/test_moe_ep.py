"""Expert-parallel (shard_map) MoE must match the single-device dispatch
MoE — run on a local (data=2, expert=2, tp=2) mesh in a subprocess."""

import subprocess
import sys
import textwrap

_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models.moe import moe_block, moe_block_ep, moe_param_defs
    from repro.models.layers import init_creator

    cfg = dataclasses.replace(get_smoke_config("grok-1-314b"),
                              capacity_factor=4.0)   # no drops
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()[:8]).reshape(2, 2, 2),
        ("data", "expert", "tp"))
    mk = init_creator(jax.random.PRNGKey(0), jnp.float32)
    p = moe_param_defs(mk, "moe", cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          jnp.float32)
    y_ref, probs_ref = moe_block(x, p, cfg, compute_dtype=jnp.float32)
    with mesh:
        y_ep, probs_ep = jax.jit(
            lambda x, p: moe_block_ep(x, p, cfg, mesh,
                                      compute_dtype=jnp.float32))(x, p)
    err = float(jnp.max(jnp.abs(y_ref - y_ep)))
    perr = float(jnp.max(jnp.abs(probs_ref - probs_ep)))
    assert err < 1e-4, f"moe_ep mismatch {err}"
    assert perr < 1e-5, f"router mismatch {perr}"
    print("MOE_EP_OK", err)
""")


def test_moe_ep_matches_dispatch_moe():
    r = subprocess.run([sys.executable, "-c", _PROG],
                       capture_output=True, text=True, timeout=600)
    assert "MOE_EP_OK" in r.stdout, (r.stdout[-1000:], r.stderr[-2000:])

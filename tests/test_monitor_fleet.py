"""Parity tests for the fused time-batched fleet monitor.

Every implementation (segmented rounds, sequential jnp scan, Pallas
kernel in interpret mode) must reproduce the float64 ``HostMonitor``
oracle and the per-sample ``run_monitor`` path on identical streams —
including convergence-reset epochs and blocked-sample discards.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.monitor import (HostMonitor, MonitorConfig,
                                fleet_monitor_init, run_monitor,
                                run_monitor_fleet)
from repro.core.simulate import (TandemConfig, sample_periods_fleet,
                                 simulate_tandem)
from repro.kernels.monitor.ops import fleet_monitor_scan

IMPLS = ["rounds", "scan", "pallas"]


def _noisy_streams(Q=5, T=700, seed=0, p_block=0.06):
    rng = np.random.default_rng(seed)
    base = rng.uniform(100, 400, (Q, 1))
    tc = rng.poisson(base, (Q, T)).astype(np.float64)
    blocked = rng.random((Q, T)) < p_block
    return tc, blocked


def _host_epochs(cfg, tc, blocked):
    """Drive the float64 HostMonitor oracle; returns epochs+estimates."""
    epochs, ests = [], []
    for q in range(tc.shape[0]):
        hm = HostMonitor(cfg)
        per_epoch = []
        for t, b in zip(tc[q], blocked[q]):
            if hm.update(float(t), bool(b)):
                per_epoch.append(hm.estimates[-1])
        epochs.append(hm.epoch)
        ests.append(per_epoch)
    return epochs, ests


@pytest.mark.parametrize("impl", IMPLS)
def test_fleet_matches_host_monitor_per_epoch(impl):
    """Fused estimates match the float64 oracle within rtol=1e-4 for
    every epoch, with epoch counts identical."""
    cfg = MonitorConfig()
    tc, blocked = _noisy_streams()
    h_epochs, h_ests = _host_epochs(cfg, tc, blocked)
    assert sum(h_epochs) >= 5      # exercise resets

    st, out = run_monitor_fleet(cfg, tc, blocked, chunk_t=256, impl=impl,
                                block_q=8)
    np.testing.assert_array_equal(np.asarray(st.epoch), h_epochs)
    conv = np.asarray(out.converged)
    est = np.asarray(out.estimate)
    for q in range(tc.shape[0]):
        got = est[q][conv[q]]
        np.testing.assert_allclose(got, h_ests[q], rtol=1e-4)


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("cfg", [MonitorConfig(),
                                 MonitorConfig(sigma_mode="stderr"),
                                 MonitorConfig.paper_faithful()])
def test_fleet_matches_run_monitor_outputs(impl, cfg):
    """(Q, T) outputs are step-for-step identical to vmap(run_monitor):
    epochs and convergence flags exact, q/q-bar/estimates to 1e-4."""
    tc, blocked = _noisy_streams(Q=4, T=600, seed=3)
    ref = jax.vmap(lambda t, b: run_monitor(cfg, t, b))(
        jnp.asarray(tc, jnp.float32), jnp.asarray(blocked))
    st, out = run_monitor_fleet(cfg, tc, blocked, chunk_t=200, impl=impl,
                                block_q=8)
    np.testing.assert_array_equal(np.asarray(out.epoch),
                                  np.asarray(ref.epoch))
    np.testing.assert_array_equal(np.asarray(out.converged),
                                  np.asarray(ref.converged))
    for name in ("q", "qbar", "estimate"):
        a = np.asarray(getattr(out, name))
        b = np.asarray(getattr(ref, name))
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-3)
    # final carried state agrees with the last outputs
    np.testing.assert_array_equal(np.asarray(st.epoch),
                                  np.asarray(ref.epoch[:, -1]))


@pytest.mark.parametrize("impl", IMPLS)
def test_fleet_blocked_samples_are_discarded(impl):
    cfg = MonitorConfig()
    Q, T = 3, 64
    tc = np.full((Q, T), 100.0)
    blocked = np.zeros((Q, T), bool)
    blocked[1] = True                    # queue 1 fully blocked
    st, out = run_monitor_fleet(cfg, tc, blocked, chunk_t=32, impl=impl,
                                block_q=8)
    assert int(st.s_fill[1]) == 0
    assert int(st.n_blocked[1]) == T
    assert int(st.s_fill[0]) == cfg.window
    assert not bool(np.asarray(out.converged)[1].any())


@pytest.mark.parametrize("impl", IMPLS)
def test_fleet_state_carries_across_dispatches(impl):
    """Chunked dispatches must agree exactly with one big dispatch."""
    cfg = MonitorConfig()
    tc, blocked = _noisy_streams(Q=3, T=512, seed=9)
    st_a, out_a = run_monitor_fleet(cfg, tc, blocked, chunk_t=512,
                                    impl=impl, block_q=8)
    st_b = fleet_monitor_init(cfg, 3)
    outs = []
    for t0 in range(0, 512, 128):
        st_b, o = fleet_monitor_scan(
            cfg, st_b, jnp.asarray(tc[:, t0:t0 + 128], jnp.float32),
            jnp.asarray(blocked[:, t0:t0 + 128]), impl=impl, block_q=8)
        outs.append(o)
    np.testing.assert_array_equal(np.asarray(st_a.epoch),
                                  np.asarray(st_b.epoch))
    ep_b = np.concatenate([np.asarray(o.epoch) for o in outs], axis=1)
    np.testing.assert_array_equal(np.asarray(out_a.epoch), ep_b)
    np.testing.assert_allclose(np.asarray(st_a.mean),
                               np.asarray(st_b.mean), rtol=2e-4, atol=1e-3)


def test_state_mode_matches_full_mode():
    cfg = MonitorConfig()
    tc, blocked = _noisy_streams(Q=4, T=400, seed=5)
    st_full, _ = run_monitor_fleet(cfg, tc, blocked, impl="rounds",
                                   mode="full")
    st_state, out = run_monitor_fleet(cfg, tc, blocked, impl="rounds",
                                      mode="state")
    assert out is None
    for a, b in zip(st_full, st_state):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_fleet_on_simulated_tandem_queues():
    """End-to-end: simulated tandem fleets converge to the configured
    consumer service rates (paper Fig. 13 tolerance)."""
    cfg = MonitorConfig()
    mus = [2.0e5, 1.5e5, 2.5e5]
    results = [simulate_tandem(TandemConfig(mu_a=2 * mu, mu_b=mu,
                                            n_items=120_000, seed=i))
               for i, mu in enumerate(mus)]
    tc, blocked = sample_periods_fleet(results, 1e-3)
    st, _ = run_monitor_fleet(cfg, tc, blocked, impl="rounds",
                              mode="state")
    assert all(int(e) >= 1 for e in np.asarray(st.epoch))
    rates = np.asarray(st.last_qbar) / 1e-3
    np.testing.assert_allclose(rates, mus, rtol=0.2)


def test_fleet_monitor_step_sigma_mode():
    """fleet_monitor_step honors MonitorConfig.sigma_mode."""
    from repro.kernels.monitor.ops import fleet_monitor_step, \
        fleet_step_init
    rng = np.random.default_rng(2)
    Q, W = 6, 32
    win = jnp.asarray(rng.uniform(50, 150, (Q, W)), jnp.float32)

    cfg_w = MonitorConfig()                        # window_std (default)
    st = fleet_step_init(cfg_w, Q)
    sigmas = []
    for _ in range(cfg_w.conv_window + 1):
        q, st, sigma = fleet_monitor_step(win, st, cfg=cfg_w)
        sigmas.append(np.asarray(sigma))
    # not enough q-bar history -> sentinel; full ring -> finite window std
    assert np.all(sigmas[0] > 1e20)
    assert np.all(sigmas[-1] < 1e20)

    cfg_s = MonitorConfig(sigma_mode="stderr")
    st = fleet_step_init(cfg_s, Q)
    q, st, sigma = fleet_monitor_step(win, st, cfg=cfg_s)
    wf = st.welford
    expect = np.sqrt(np.maximum(np.asarray(wf.m2), 0)
                     / np.asarray(wf.count) ** 2)
    np.testing.assert_allclose(np.asarray(sigma), expect, rtol=1e-5,
                               atol=1e-7)


def test_fleet_monitor_service_over_instrumented_queues():
    """streams.FleetMonitorService: one sampling loop, batched estimator."""
    from repro.streams import FleetMonitorService, InstrumentedQueue

    queues = [InstrumentedQueue(capacity=8) for _ in range(3)]
    rates = [120, 240, 360]
    emitted = []
    svc = FleetMonitorService(queues, MonitorConfig(), period_s=1e-3,
                              chunk_t=32, scale_to_period=False,
                              on_converged=lambda qi, r:
                              emitted.append((qi, r)))
    for step in range(150):
        for queue, rate in zip(queues, rates):
            for _ in range(rate):
                queue.push(object())
                queue.pop()
        svc.sample()
    svc.flush()
    assert len(svc) == 3
    eps = svc.epochs()
    assert (eps >= 1).all()
    assert emitted and {qi for qi, _ in emitted} <= {0, 1, 2}
    got = svc.rates_items_per_s() * 1e-3      # items/period
    np.testing.assert_allclose(got, rates, rtol=0.05)

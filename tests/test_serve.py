import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import Engine, Request, ServeConfig


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke_config("internlm2-1.8b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(batch_size=4, max_seq=64,
                                            queue_capacity=16)).start()
    yield eng, model, params, cfg
    eng.stop()


def test_engine_serves_batched_requests(engine):
    eng, model, params, cfg = engine
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size, size=8),
                    max_new=4) for i in range(6)]
    for r in reqs:
        assert eng.submit(r)
    for r in reqs:
        assert r.done.wait(timeout=120), "request timed out"
        assert r.out is not None and r.out.shape == (4,)
    assert eng.served >= 6


def test_engine_greedy_matches_direct_decode(engine):
    eng, model, params, cfg = engine
    toks = np.arange(1, 9) % cfg.vocab_size
    req = Request(rid=99, tokens=toks, max_new=3)
    eng.submit(req)
    assert req.done.wait(timeout=120)
    # direct: prefill + greedy decode with the same model
    logits, cache = model.prefill(params,
                                  {"tokens": jnp.asarray(toks)[None]})
    cache = jax.tree_util.tree_map(
        lambda v: (jnp.pad(v, [(0, 0), (0, 0), (0, 64 - v.shape[2]),
                               (0, 0), (0, 0)])
                   if v.ndim >= 3 and v.shape[2] == 8 else v), cache)
    cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    outs = [int(cur[0])]
    pos = jnp.asarray([8], jnp.int32)
    for _ in range(2):
        cur, cache = model.decode_step(params, cache, cur, pos)
        pos = pos + 1
        outs.append(int(cur[0]))
    np.testing.assert_array_equal(req.out[:3], outs)


def test_engine_monitor_surfaces_rates(engine):
    eng, *_ = engine
    # after the previous tests the request-queue monitor has samples
    assert eng.queue.head.tc >= 0
    assert eng.recommended_queue_capacity() >= 1


def test_engine_latency_stats_reads_arena_histograms(engine):
    """PR 9 satellite: latency_stats() reads the lane head-slot
    histogram rows in the shared counter arena — the same columns the
    fleet collector harvests — so serve and control report one latency
    truth, with bucket-interpolated percentiles."""
    from repro.streams.arena import hist_quantiles
    eng, model, params, cfg = engine
    rng = np.random.default_rng(3)
    reqs = [Request(rid=1000 + i,
                    tokens=rng.integers(0, cfg.vocab_size, size=8),
                    max_new=2) for i in range(3)]
    for r in reqs:
        assert eng.submit(r)
    for r in reqs:
        assert r.done.wait(timeout=120)
    stats = eng.latency_stats()
    assert set(stats) == set(eng.class_names)
    total = 0
    for n in eng.class_names:
        hist = eng.lanes[n].head.latency_histogram()
        s = stats[n]
        assert s["n"] == int(hist.sum())          # arena is the truth
        if s["n"]:
            q = hist_quantiles(hist[None, :].astype(np.int64),
                               (0.5, 0.99))[0]
            assert s["p50"] == pytest.approx(float(q[0]))
            assert s["p99"] == pytest.approx(float(q[1]))
            assert 0 < s["p50"] <= s["p99"]
        else:
            assert s["p50"] == 0.0 and s["p99"] == 0.0
        total += s["n"]
    assert total >= 3                             # our requests landed

import numpy as np
import pytest
from scipy import signal

from repro.core.filters import (convolve_valid, gaussian_filter_valid,
                                gaussian_kernel, log_filter_valid,
                                log_kernel)


def test_gaussian_kernel_matches_eq2():
    """Eq. 2 verbatim: exp(-x^2/2)/sqrt(2pi) at x in [-2, 2]."""
    k = gaussian_kernel(2, 1.0, normalize=False)
    x = np.arange(-2, 3, dtype=float)
    expected = np.exp(-x ** 2 / 2) / np.sqrt(2 * np.pi)
    np.testing.assert_allclose(k, expected, rtol=1e-12)
    assert abs(k.sum() - 0.9913) < 1e-3      # raw kernel sums to ~.9913


def test_gaussian_kernel_normalized_sums_to_one():
    assert abs(gaussian_kernel(2).sum() - 1.0) < 1e-12


def test_log_kernel_matches_eq4():
    """Eq. 4 with sigma = 1/2 at x in [-1, 1]."""
    k = log_kernel(1, 0.5)
    s = 0.5
    x = np.arange(-1, 2, dtype=float)
    g = np.exp(-x ** 2 / (2 * s * s)) / np.sqrt(2 * np.pi)
    expected = x ** 2 * g / s ** 5 - g / s ** 3
    np.testing.assert_allclose(k, expected, rtol=1e-12)
    # center strongly negative, symmetric positive lobes: edge detector
    assert k[1] < 0 < k[0] == pytest.approx(k[2])


def test_convolve_valid_matches_scipy():
    rng = np.random.default_rng(0)
    x = rng.normal(size=64)
    k = gaussian_kernel(2)
    ours = np.asarray(convolve_valid(x, k))
    ref = signal.correlate(x, k, mode="valid")
    np.testing.assert_allclose(ours, ref, rtol=1e-10, atol=1e-12)
    assert ours.shape[0] == 64 - 4           # width shrinks by 2*radius


def test_filter_width_contract():
    x = np.ones(32)
    assert gaussian_filter_valid(x).shape == (28,)
    assert log_filter_valid(np.ones(18)).shape == (16,)


def test_gaussian_filter_preserves_constant():
    np.testing.assert_allclose(gaussian_filter_valid(np.full(32, 7.0)),
                               7.0, rtol=1e-6)


def test_log_filter_zero_on_constant_iff_kernel_sum():
    k = log_kernel(1, 0.5)
    resp = np.asarray(log_filter_valid(np.full(18, 3.0)))
    np.testing.assert_allclose(resp, 3.0 * k.sum(), rtol=1e-9)

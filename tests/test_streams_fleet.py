"""Fleet monitoring hot path + vectorized control plane (PR 2).

Covers the batched collector under blocked-sample bursts and mid-stream
stage failure (``ft.failures`` injection), parity of the
pipeline-integrated estimates against the sequential scan oracle, the
recompile-count contract for ragged fleets, and the readiness-gated
pre-convergence readouts.
"""

import numpy as np
import pytest

from repro.core.monitor import (MonitorConfig, fleet_dispatch_trace_count,
                                fleet_rate_readout, run_monitor_fleet)
from repro.ft import FleetRateTracker
from repro.streams import (FleetMonitorService, FleetMonitorThread,
                           InstrumentedQueue, Pipeline, Stage)


def _drive_service(tc, blocked, cfg, chunk_t=32, **kw):
    """Replay a synthetic (Q, T) sample stream through the batched
    collector exactly as a pipeline tick would produce it."""
    Q, T = tc.shape
    queues = [InstrumentedQueue(8) for _ in range(Q)]
    svc = FleetMonitorService(queues, cfg, period_s=1e-3, chunk_t=chunk_t,
                              scale_to_period=False, **kw)
    for t in range(T):
        for qi, q in enumerate(queues):
            q.head.tc = float(tc[qi, t])
            q.head.blocked = bool(blocked[qi, t])
        svc.sample()
    svc.flush()
    return svc


def test_service_blocked_bursts_match_scan_oracle():
    """Pipeline-integrated estimates == sequential scan oracle (rtol
    1e-4) on streams with a long full-block burst and background
    blocking; epochs identical, healthy queues unaffected."""
    cfg = MonitorConfig()
    rng = np.random.default_rng(7)
    Q, T = 6, 640
    tc = rng.poisson(rng.uniform(100, 400, (Q, 1)), (Q, T)).astype(float)
    blocked = rng.random((Q, T)) < 0.05
    blocked[2, 100:260] = True          # mid-stream blocked burst
    blocked[4, 500:] = True             # stalls near the end

    svc = _drive_service(tc, blocked, cfg, chunk_t=32)
    st, _ = run_monitor_fleet(cfg, tc, blocked, impl="scan",
                              mode="state", chunk_t=128, block_q=8)

    np.testing.assert_array_equal(svc.epochs(), np.asarray(st.epoch))
    assert svc.epochs().min() >= 1      # bursts did not stall convergence
    conv = svc.epochs() > 0
    got = svc.service_rates() * svc.period_s        # items/period
    want = np.asarray(st.last_qbar)
    np.testing.assert_allclose(got[conv], want[conv], rtol=1e-4)
    # burst periods were discarded, not folded
    frac = svc.observed_blocking_fraction()
    assert frac[2] > 0.2 and frac[0] < 0.15


def test_service_stage_failure_ft_injection():
    """A consumer stage dying mid-stream turns its queue head into a
    permanently blocked stream: the fleet keeps estimating the healthy
    queues, the dead queue's epochs freeze, and the ft straggler path
    flags the phase-changed host."""
    cfg = MonitorConfig(window=16, min_q_samples=16)
    rng = np.random.default_rng(3)
    Q, T = 5, 400
    tc = rng.poisson(200, (Q, T)).astype(float)
    blocked = np.zeros((Q, T), bool)
    fail_at = 120
    tc[3, fail_at:] = 0.0               # stage 3's consumer dies
    blocked[3, fail_at:] = True

    svc = _drive_service(tc, blocked, cfg, chunk_t=32)
    st, _ = run_monitor_fleet(cfg, tc, blocked, impl="scan",
                              mode="state", block_q=8)
    np.testing.assert_array_equal(svc.epochs(), np.asarray(st.epoch))
    healthy = [q for q in range(Q) if q != 3]
    assert svc.epochs()[healthy].min() >= 1
    # the dead queue blocks from fail_at on
    assert svc.observed_blocking_fraction()[3] == pytest.approx(
        (T - fail_at) / T)

    # ft.failures injection: per-host step streams through the fleet
    # tracker — host 3's rate phase-changes down and is flagged
    hosts = [f"h{i}" for i in range(Q)]
    tracker = FleetRateTracker(hosts, cfg, period_s=1.0, chunk_t=16,
                               impl="rounds")
    steps = np.full((Q, 600), 100.0) + rng.normal(0, 1.0, (Q, 600))
    steps[3, 200:] *= 0.3               # straggler phase change
    for t0 in range(0, 600, 100):
        tracker.record_tile(steps[:, t0:t0 + 100])
    assert tracker.stragglers() == ["h3"]
    rates = tracker.rates()
    assert rates[3] < 0.5 * np.median(rates[[0, 1, 2, 4]])


def test_service_rates_pre_convergence_gated():
    """Regression (satellite 1): before convergence the readout must be
    gated on the Welford count — a handful of q-folds is a raw sample,
    not an estimate, and reports 0."""
    cfg = MonitorConfig()               # min_q_samples = 32
    rng = np.random.default_rng(0)
    Q = 2
    queues = [InstrumentedQueue(8) for _ in range(Q)]
    svc = FleetMonitorService(queues, cfg, period_s=1e-3, chunk_t=8,
                              scale_to_period=False)

    def feed(n):
        for _ in range(n):
            for q in queues:
                q.head.tc = float(rng.uniform(50, 150))
            svc.sample()
        svc.flush()

    # window filled but only a few folds: count < min_q_samples -> 0
    feed(cfg.window + 8)
    assert (svc.epochs() == 0).all()
    np.testing.assert_array_equal(svc.service_rates(), 0.0)

    # past the count gate the running q-bar becomes visible even before
    # the first convergence (high-variance stream stays unconverged)
    feed(64)
    state = svc.state_snapshot()
    count = np.asarray(state.count)
    assert (count >= cfg.min_q_samples).all()
    rates = svc.service_rates()
    assert (rates > 0).all()
    pre = svc.epochs() == 0
    expect = np.asarray(state.mean) / svc.period_s
    np.testing.assert_allclose(rates[pre], expect[pre], rtol=1e-6)


def test_engine_service_rate_pre_convergence_gate():
    """Regression (satellite 1): a fresh engine reports 0 requests/s and
    keeps its configured capacity instead of echoing raw samples."""
    from repro.serve import Engine, ServeConfig

    class _Cfg:
        vocab_size = 16

    class _FakeModel:
        cfg = _Cfg()

        def prefill(self, params, batch):
            raise NotImplementedError

        def decode_step(self, params, cache, tok, pos):
            raise NotImplementedError

    eng = Engine(_FakeModel(), None,
                 ServeConfig(batch_size=2, max_seq=32, queue_capacity=8))
    assert eng.service_rate() == 0.0
    assert eng.recommended_queue_capacity() == 8


def test_pipeline_rates_pre_convergence_gated():
    pipe = Pipeline([Stage("src", source=range(10)),
                     Stage("id", fn=lambda x: x)], capacity=8)
    rates = pipe.rates()
    assert len(rates) == 2
    for entry in rates.values():
        assert entry["service_rate"] == 0.0
        assert entry["arrival_rate"] == 0.0
        assert entry["epochs"] == 0


def test_ragged_fleet_does_not_retrace():
    """Satellite 2: the jitted fleet step is cached per (block_q,
    chunk_t, config); varying Q across calls must not retrace."""
    cfg = MonitorConfig(window=8, min_q_samples=8)   # fresh cache key
    rng = np.random.default_rng(0)

    def run(q):
        tc = rng.poisson(50, (q, 64)).astype(float)
        blk = rng.random((q, 64)) < 0.1
        run_monitor_fleet(cfg, tc, blk, chunk_t=32, impl="rounds",
                          mode="state", block_q=16)

    base = fleet_dispatch_trace_count()
    run(3)
    warm = fleet_dispatch_trace_count()
    assert warm > base                   # first call traced
    for q in (5, 9, 16, 2, 11):
        run(q)
    assert fleet_dispatch_trace_count() == warm   # ragged Q: no retrace


def test_ragged_services_share_one_dispatch():
    """Different-size FleetMonitorServices with the same static knobs
    ride the same compiled dispatch."""
    cfg = MonitorConfig(window=8, min_q_samples=8)

    def drive(q):
        queues = [InstrumentedQueue(4) for _ in range(q)]
        svc = FleetMonitorService(queues, cfg, period_s=1e-3, chunk_t=8,
                                  scale_to_period=False, block_q=16)
        for t in range(16):
            for qu in queues:
                qu.head.tc = 10.0
            svc.sample()
        svc.flush()

    drive(3)
    warm = fleet_dispatch_trace_count()
    for q in (5, 7, 2):
        drive(q)
    assert fleet_dispatch_trace_count() == warm


def test_state_snapshot_survives_donated_dispatch():
    """Regression: readouts must materialize the state under the lock —
    the live state's buffers are donated into the next dispatch (no-pad
    shapes donate the service's arrays directly), so a held reference
    would raise "Array has been deleted"."""
    cfg = MonitorConfig(window=8, min_q_samples=8)
    queues = [InstrumentedQueue(4)]
    # 2 streams with block_q=2: rpad == 0, donation hits the live state
    svc = FleetMonitorService(queues, cfg, period_s=1e-3, chunk_t=8,
                              scale_to_period=False, ends="both",
                              block_q=2)

    def feed(n):
        for _ in range(n):
            queues[0].head.tc = 10.0
            queues[0].tail.tc = 10.0
            svc.sample()

    feed(8)                             # first dispatch
    snap = svc.state_snapshot()
    feed(16)                            # two more dispatches donate
    svc.flush()
    # the snapshot must still be readable after its source was donated
    assert np.isfinite(snap.mean).all()
    assert np.isfinite(svc.service_rates()).all()
    assert np.isfinite(svc.observed_blocking_fraction()).all()


def test_fleet_thread_drives_pipeline_service():
    """End-to-end: the timer thread + batched collector + fused dispatch
    over live queues converges to the synthetic service rates."""
    cfg = MonitorConfig(window=16, min_q_samples=16)
    queues = [InstrumentedQueue(capacity=8) for _ in range(3)]
    svc = FleetMonitorService(queues, cfg, period_s=1e-3, chunk_t=16,
                              ends="both")
    thread = FleetMonitorThread(svc, adapt_period=False)
    thread.start()
    import time
    deadline = time.monotonic() + 20.0
    while svc.epochs()[:3].min() < 1 and time.monotonic() < deadline:
        for queue, rate in zip(queues, (40, 80, 120)):
            for _ in range(rate):
                queue.push(object())
                queue.pop()
        time.sleep(1e-3)
    thread.stop()
    assert svc.epochs()[:3].min() >= 1
    mu = svc.service_rates()
    lam = svc.arrival_rates()
    assert mu.shape == lam.shape == (3,)
    assert (mu > 0).all()
    # relative ordering of the three synthetic rates must be preserved
    assert mu[0] < mu[1] < mu[2]


def test_pipeline_autotune_vectorized():
    """The vectorized control plane runs end-to-end: a live pipeline
    with autotuning resizes through maybe_resize_fleet without error and
    produces correct results."""
    pipe = Pipeline([Stage("src", source=range(4000)),
                     Stage("x3", fn=lambda x: x * 3)], capacity=64,
                    base_period_s=1e-3, autotune=True,
                    monitor_cfg=MonitorConfig(window=16, min_q_samples=16))
    out = pipe.run_collect(timeout_s=60)
    assert sorted(out) == [3 * i for i in range(4000)]
    reps = pipe.recommended_replicas()
    assert set(reps) == {"x3"}
    assert reps["x3"] >= 1
    assert (pipe._capacities >= pipe.tuner.min_capacity).all()


def test_fleet_service_live_attach_preserves_estimates():
    """Multi-tenant restructure (PR 5): attaching queues to a live
    service keeps every retained stream's Algorithm-1 state (epochs,
    gated estimates) bit-for-bit, folds the in-flight partial chunk
    first, and lets the new queues converge from a clean init."""
    from repro.streams import CounterArena

    cfg = MonitorConfig(window=16, min_q_samples=16)
    arena = CounterArena(16)
    q_old = [InstrumentedQueue(8, arena=arena) for _ in range(2)]
    svc = FleetMonitorService(q_old, cfg, period_s=1e-3, chunk_t=16,
                              scale_to_period=False, ends="both")

    def feed(queues, rates, n):
        for _ in range(n):
            for q, r in zip(queues, rates):
                q.head.tc = float(r)
                q.tail.tc = float(r)
            svc.sample()

    feed(q_old, [100.0, 200.0], 203)     # 203: partial chunk in flight
    svc.flush()
    before_rates = svc.gated_rates().copy()
    before_epochs = svc.epochs().copy()
    assert (before_rates > 0).all()

    q_new = InstrumentedQueue(8, arena=arena)
    svc.attach([q_new])
    assert len(svc.queues) == 3 and svc.n_streams == 6
    # retained streams: heads 0-1 and tails now at 3-4
    after = svc.gated_rates()
    np.testing.assert_allclose(after[[0, 1, 3, 4]],
                               before_rates[[0, 1, 2, 3]], rtol=1e-6)
    np.testing.assert_array_equal(svc.epochs()[[0, 1, 3, 4]],
                                  before_epochs[[0, 1, 2, 3]])
    assert after[2] == 0.0 and after[5] == 0.0   # fresh queue: unready

    feed(svc.queues, [100.0, 200.0, 300.0], 200)
    svc.flush()
    rates = svc.gated_rates() * svc.period_s
    np.testing.assert_allclose(rates[:3], [100, 200, 300], rtol=0.05)

    # detach the middle queue: remaining order preserved, end unpinned
    svc.detach([q_old[1]])
    assert len(svc.queues) == 2
    rates2 = svc.gated_rates() * svc.period_s
    np.testing.assert_allclose(rates2[:2], [100, 300], rtol=0.05)
    q_old[1].close()                     # detached => slot recycles
    with pytest.raises(ValueError, match="monitors"):
        q_old[0].close()                 # still monitored => pinned
    svc.stop()
    q_old[0].close()


def test_fleet_service_attach_from_empty():
    """A service born empty (the ControlGroup posture) samples as a
    no-op, then monitors normally after the first attach."""
    from repro.streams import CounterArena

    cfg = MonitorConfig(window=16, min_q_samples=16)
    arena = CounterArena(8)
    svc = FleetMonitorService([], cfg, period_s=1e-3, chunk_t=8,
                              scale_to_period=False, ends="both")
    for _ in range(20):                  # empty ticks cross chunk edges
        assert svc.sample() is False
    svc.flush()
    q = InstrumentedQueue(8, arena=arena)
    svc.attach([q])
    for _ in range(200):
        q.head.tc = 50.0
        q.tail.tc = 50.0
        svc.sample()
    svc.flush()
    assert (svc.gated_rates() > 0).all()
    svc.stop()
